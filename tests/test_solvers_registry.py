"""Unified repro.solvers API: registry, lifecycle, solve_many, warm starts."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro import solvers
from repro.core import apc, baselines, precond
from repro.core.partition import BlockSystem
from repro.data import linsys

ALL = ["apc", "cimmino", "consensus", "dgd", "dhbm", "dnag", "madmm", "pdhbm"]

# Iteration budgets for a rel-residual < 1e-6 on the well-conditioned fixture
# (the slow methods of the paper — DGD, M-ADMM, plain consensus — need more).
ITERS = {"apc": 400, "dhbm": 600, "dnag": 800, "pdhbm": 500, "cimmino": 2500,
         "consensus": 2500, "dgd": 4000, "madmm": 12000}


@pytest.fixture(scope="module")
def sys_():
    return linsys.conditioned_gaussian(n=80, m=4, cond=10.0, seed=11)


def test_registry_lists_all_eight():
    assert solvers.available() == ALL
    with pytest.raises(KeyError):
        solvers.get("nope")


@pytest.mark.parametrize("name", ALL)
def test_lifecycle_roundtrip_and_convergence(sys_, name):
    """prepare -> init -> step manually, and the solve() driver, both work;
    the solver reaches residual < 1e-6 through the identical call path."""
    s = solvers.get(name)
    prm = s.resolve_params(sys_)
    factors = s.prepare(sys_.A_blocks, prm)
    state = s.init(factors, sys_.b_blocks, prm)
    for _ in range(3):
        state = s.step(factors, sys_.b_blocks, state, prm)
    assert s.extract(state).shape == (sys_.n,)
    assert int(state.t) == 3

    res = s.solve(sys_, iters=ITERS[name])
    assert res.name == name
    assert res.params.keys() >= set(s.param_names)
    assert float(res.residuals[-1]) < 1e-6, name
    assert res.iters_to_tol != -1 and res.iters_to_tol <= ITERS[name]


@pytest.mark.parametrize("name,legacy", [
    ("apc", lambda s, it: apc.solve(s, iters=it)),
    ("dgd", lambda s, it: baselines.dgd(s, iters=it)),
    ("dnag", lambda s, it: baselines.dnag(s, iters=it)),
    ("dhbm", lambda s, it: baselines.dhbm(s, iters=it)),
    ("madmm", lambda s, it: baselines.madmm(s, iters=it)),
    ("cimmino", lambda s, it: baselines.cimmino(s, iters=it)),
    ("consensus", lambda s, it: baselines.consensus(s, iters=it)),
    ("pdhbm", lambda s, it: precond.preconditioned_dhbm(s, iters=it)),
])
def test_agrees_with_legacy_entry_point(sys_, name, legacy):
    """The deprecated shims route every kwarg to the registry unchanged.

    (The legacy entry points now delegate to the registry, so this checks
    the shim plumbing, not an independent implementation — the independent
    math cross-check is test_three_steps_match_numpy_reference below.)
    """
    r_new = solvers.get(name).solve(sys_, iters=120)
    r_old = legacy(sys_, 120)
    assert float(jnp.linalg.norm(r_new.x - r_old.x)) < 1e-10
    np.testing.assert_allclose(np.asarray(r_new.residuals),
                               np.asarray(r_old.residuals), atol=1e-10)


def _numpy_reference(name, A, b, params, iters):
    """Literal numpy transcription of the paper's update equations."""
    m, p, n = A.shape
    G = np.stack([A[i] @ A[i].T for i in range(m)])
    Gi = np.stack([np.linalg.inv(G[i]) for i in range(m)])

    def grad(Ab, bb, x):
        return sum(Ab[i].T @ (Ab[i] @ x - bb[i]) for i in range(m))

    if name == "dgd":
        x = np.zeros(n)
        for _ in range(iters):
            x = x - params["alpha"] * grad(A, b, x)
        return x
    if name == "dnag":
        x = y_prev = np.zeros(n)
        for _ in range(iters):
            y = x - params["alpha"] * grad(A, b, x)
            x = (1 + params["beta"]) * y - params["beta"] * y_prev
            y_prev = y
        return x
    if name == "dhbm":
        x = z = np.zeros(n)
        for _ in range(iters):
            z = params["beta"] * z + grad(A, b, x)
            x = x - params["alpha"] * z
        return x
    if name == "pdhbm":
        C = np.empty_like(A)
        d = np.empty_like(b)
        for i in range(m):
            w, V = np.linalg.eigh(G[i])
            S = (V / np.sqrt(w)) @ V.T
            C[i], d[i] = S @ A[i], S @ b[i]
        return _numpy_reference("dhbm", C, d, params, iters)
    if name == "cimmino":
        xbar = np.zeros(n)
        for _ in range(iters):
            xbar = xbar + params["nu"] * sum(
                A[i].T @ (Gi[i] @ (b[i] - A[i] @ xbar)) for i in range(m))
        return xbar
    if name == "madmm":
        xi = params["xi"]
        xbar = np.zeros(n)
        inv = [np.linalg.inv(A[i].T @ A[i] + xi * np.eye(n)) for i in range(m)]
        for _ in range(iters):
            xbar = np.mean([inv[i] @ (A[i].T @ b[i] + xi * xbar)
                            for i in range(m)], axis=0)
        return xbar
    if name in ("apc", "consensus"):
        gamma, eta = params["gamma"], params["eta"]
        P = [np.eye(n) - A[i].T @ Gi[i] @ A[i] for i in range(m)]
        x = np.stack([A[i].T @ (Gi[i] @ b[i]) for i in range(m)])
        xbar = x.mean(axis=0)
        for _ in range(iters):
            x = np.stack([x[i] + gamma * (P[i] @ (xbar - x[i]))
                          for i in range(m)])
            xbar = eta * x.mean(axis=0) + (1 - eta) * xbar
        return xbar
    raise KeyError(name)


@pytest.mark.parametrize("name", ALL)
def test_three_steps_match_numpy_reference(sys_, name):
    """Independent cross-check: the registry's iterates equal a literal
    numpy transcription of the paper's equations (Sec 3-4, 6)."""
    s = solvers.get(name)
    prm = s.resolve_params(sys_)
    res = s.solve(sys_, iters=3, **prm)
    ref = _numpy_reference(name, np.asarray(sys_.A_blocks, np.float64),
                           np.asarray(sys_.b_blocks, np.float64), prm, 3)
    np.testing.assert_allclose(np.asarray(res.x), ref, rtol=1e-9, atol=1e-9)


@pytest.mark.parametrize("name", ALL)
def test_solve_many_matches_single_rhs(sys_, name):
    """Batched multi-RHS shares ONE prepare() and matches per-RHS solves."""
    s = solvers.get(name)
    rng = np.random.default_rng(4)
    B = rng.standard_normal((8, sys_.N))

    calls = []
    cls = type(s)
    orig = cls.prepare

    def counting(self, A, prm):
        calls.append(1)
        return orig(self, A, prm)

    cls.prepare = counting
    try:
        rb = s.solve_many(sys_, B, iters=150)
    finally:
        cls.prepare = orig
    assert len(calls) == 1, "solve_many must factorize exactly once"
    assert rb.x.shape == (8, sys_.n)
    assert rb.residuals.shape == (8, 150)

    prm = s.resolve_params(sys_)
    for i in (0, 3, 7):
        si = BlockSystem(sys_.A_blocks,
                         jnp.asarray(B[i]).reshape(sys_.m, sys_.p))
        ri = s.solve(si, iters=150, **prm)
        assert float(jnp.linalg.norm(rb.x[i] - ri.x)) < 1e-10
        np.testing.assert_allclose(np.asarray(rb.residuals[i]),
                                   np.asarray(ri.residuals), atol=1e-10)


@pytest.mark.parametrize("name", ALL)
def test_warm_start_resumes_exactly(sys_, name):
    """50 + 50 warm-started iterations == 100 uninterrupted ones."""
    s = solvers.get(name)
    prm = s.resolve_params(sys_)
    r_full = s.solve(sys_, iters=100, **prm)
    r_half = s.solve(sys_, iters=50, **prm)
    r_resumed = s.solve(sys_, iters=50, warm_state=r_half.state, **prm)
    assert float(jnp.linalg.norm(r_full.x - r_resumed.x)) == 0.0
    assert int(r_resumed.state.t) == 100


def test_warm_start_through_checkpoint(sys_, tmp_path):
    """SolveResult.state round-trips repro.checkpoint and resumes exactly."""
    from repro.checkpoint import ckpt
    s = solvers.get("apc")
    r1 = s.solve(sys_, iters=40, gamma=1.3, eta=1.2)
    ckpt.save(str(tmp_path), 40, r1.state)
    restored = ckpt.restore(str(tmp_path), r1.state)
    r2 = s.solve(sys_, iters=40, gamma=1.3, eta=1.2, warm_state=restored)
    r_full = s.solve(sys_, iters=80, gamma=1.3, eta=1.2)
    assert float(jnp.linalg.norm(r2.x - r_full.x)) == 0.0


def test_kernel_flag_uniform_on_projection_family(sys_):
    for name in ("apc", "consensus", "cimmino"):
        s = solvers.get(name)
        assert s.supports_kernel
        r1 = s.solve(sys_, iters=40)
        r2 = s.solve(sys_, iters=40, use_kernel=True)
        assert float(jnp.linalg.norm(r1.x - r2.x)) < 1e-8, name
    with pytest.raises(ValueError):
        solvers.get("dgd").solve(sys_, iters=5, use_kernel=True)


def test_iters_to_tolerance_semantics(sys_):
    r = solvers.get("apc").solve(sys_, iters=300, tol=1e-6)
    k = r.iters_to_tol
    assert k != -1
    res = np.asarray(r.residuals)
    assert res[k - 1] < 1e-6 and (k == 1 or res[k - 2] >= 1e-6)
    assert r.iters_to(1e300) == 1
    assert r.iters_to(0.0) == -1


def test_never_reached_sentinel_uniform_across_drivers(sys_):
    """solve and solve_many use the SAME -1 sentinel for "never reached",
    so downstream comparisons cannot silently disagree between drivers."""
    s = solvers.get("dgd")
    r1 = s.solve(sys_, iters=3, tol=1e-30)
    assert r1.iters_to_tol == -1
    B = np.random.default_rng(0).standard_normal((4, sys_.N))
    rb = s.solve_many(sys_, B, iters=3, tol=1e-30)
    got = np.asarray(rb.iters_to_tol)
    assert got.shape == (4,) and (got == -1).all()
    # reached case stays a positive 1-based count in both drivers
    r2 = s.solve(sys_, iters=3, tol=1e300)
    rb2 = s.solve_many(sys_, B, iters=3, tol=1e300)
    assert r2.iters_to_tol == 1 and (np.asarray(rb2.iters_to_tol) == 1).all()


def test_theoretical_rates_match_spectral_summary(sys_):
    from repro.core import spectral
    s = spectral.rates_summary(sys_)
    for name, key in [("apc", "APC"), ("dgd", "DGD"), ("dnag", "D-NAG"),
                      ("dhbm", "D-HBM"), ("cimmino", "B-Cimmino"),
                      ("consensus", "Consensus")]:
        rho = solvers.get(name).theoretical_rate(sys_)
        assert rho == pytest.approx(s[key], rel=1e-12), name
