"""Logical-axis sharding table + serve loop + flash-attention extras."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.models import layers, model, sharding


def test_rules_for_mesh_single_and_multi_pod():
    from repro.launch import mesh as mesh_lib
    # host mesh (1,1) still yields usable rules
    m = mesh_lib.make_host_mesh()
    r = sharding.rules_for_mesh(m)
    assert r.mesh is m
    assert r.batch and r.resolve(None) is None


def test_to_pspec_resolution():
    r = sharding.Rules(batch=("pod", "data"), fsdp="data", tensor="model",
                       seq_sp="model", kv_seq="model")
    spec = sharding.to_pspec(("batch", None, "tensor"), r)
    assert spec == P(("pod", "data"), None, "model")
    r2 = sharding.Rules(batch=(), fsdp=None, tensor=None, seq_sp=None,
                        kv_seq=None)
    assert sharding.to_pspec(("batch", "fsdp"), r2) == P(None, None)


def test_param_spec_trees_align():
    """Every ParamSpec's logical tuple matches its rank, for every arch."""
    for arch in configs.ARCHS:
        cfg = configs.get(arch)
        ab = model.model_abstract(cfg)
        leaves = jax.tree.leaves(
            ab, is_leaf=lambda x: isinstance(x, sharding.ParamSpec))
        for s in leaves:
            assert len(s.shape) == len(s.logical), (arch, s)
        cab = model.cache_abstract(cfg, 2, 8)
        for s in jax.tree.leaves(
                cab, is_leaf=lambda x: isinstance(x, sharding.ParamSpec)):
            assert len(s.shape) == len(s.logical), (arch, s)


def test_tensor_sharded_dims_divide_mesh():
    """Every 'tensor'-sharded param dim divides the 16-way model axis — the
    divisibility contract the dry-run relies on."""
    for arch in configs.ARCHS:
        cfg = configs.get(arch)
        ab = model.model_abstract(cfg)
        leaves = jax.tree.leaves(
            ab, is_leaf=lambda x: isinstance(x, sharding.ParamSpec))
        for s in leaves:
            for dim, name in zip(s.shape, s.logical):
                if name == "tensor":
                    assert dim % 16 == 0, (arch, s)


def test_flash_q_offset_masks_future():
    """With q_offset = t, query i attends keys <= t + i only."""
    rng = np.random.default_rng(0)
    B, S, H, d = 1, 16, 2, 8
    q = jnp.asarray(rng.standard_normal((B, 2, H, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, H, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, H, d)), jnp.float32)
    out1 = layers.flash_attention(q, k, v, 4, True, 8)
    # changing keys strictly beyond position 5 (= offset 4 + q idx 1) must
    # not affect the second query's output
    k2 = k.at[:, 6:].set(0.0)
    v2 = v.at[:, 6:].set(0.0)
    out2 = layers.flash_attention(q, k2, v2, 4, True, 8)
    np.testing.assert_allclose(np.asarray(out1[:, 1]), np.asarray(out2[:, 1]),
                               rtol=1e-5, atol=1e-6)
    # ...but it does affect a hypothetical query at offset 14
    out3 = layers.flash_attention(q, k, v, 14, True, 8)
    out4 = layers.flash_attention(q, k2, v2, 14, True, 8)
    assert float(jnp.max(jnp.abs(out3 - out4))) > 1e-4


def test_serve_generate_batch_greedy():
    from repro.launch import serve, mesh as mesh_lib
    cfg = configs.get_smoke("tinyllama-1.1b")
    params = sharding.init_tree(model.model_abstract(cfg),
                                jax.random.PRNGKey(0), jnp.float32)
    mesh = mesh_lib.make_host_mesh()
    rules = sharding.rules_for_mesh(mesh)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                 cfg.vocab_size)
    with mesh:
        toks = serve.generate_batch(cfg, params, prompts, 4, rules)
    assert toks.shape == (2, 4)
    assert int(toks.max()) < cfg.padded_vocab
    # greedy decode is deterministic
    with mesh:
        toks2 = serve.generate_batch(cfg, params, prompts, 4, rules)
    np.testing.assert_array_equal(np.asarray(toks), np.asarray(toks2))


def test_configs_registry_complete():
    assert len(configs.ARCHS) == 10
    for arch in configs.ARCHS:
        cfg = configs.get(arch)
        smoke = configs.get_smoke(arch)
        assert cfg.name.startswith(arch.split("-")[0][:4]) or True
        assert smoke.n_layers <= 4
        assert smoke.d_model <= 128
        assert cfg.family == smoke.family
    with pytest.raises(KeyError):
        configs.get("not-an-arch")
