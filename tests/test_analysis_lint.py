"""Tests for repro.analysis: every lint rule against its corpus pair,
the suppression path, the lock checker, the live-repo-clean gate, the
CLI exit codes, and attributed tracecheck assertions."""
import pathlib

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import (TraceError, check_locks, lint_file, lint_paths,
                            tracecheck)
from repro.analysis.__main__ import main as lint_main
from repro.analysis.lint import Finding, SourceFile

CORPUS = pathlib.Path(__file__).parent / "lint_corpus"

RULE_CASES = [
    ("R001", "r001_bad.py", "r001_ok.py"),
    ("R002", "r002_bad.py", "r002_ok.py"),
    ("R003", "r003_bad.py", "r003_ok.py"),
    ("R004", "r004_bad.py", "r004_ok.py"),
    ("R005", "core/r005_bad.py", "core/r005_ok.py"),
    ("R006", "r006_bad.py", "r006_ok.py"),
    ("R007", "r007_bad.py", "r007_ok.py"),
    ("R008", "r008_bad.py", "r008_ok.py"),
    ("R009", "repro/r009_bad.py", "repro/r009_ok.py"),
]


# ---------------------------------------------------------------- rules
@pytest.mark.parametrize("rule,bad,ok", RULE_CASES,
                         ids=[c[0] for c in RULE_CASES])
def test_rule_fires_on_violation_and_not_on_conforming(rule, bad, ok):
    bad_hits = [f for f in lint_file(CORPUS / bad) if f.rule == rule]
    assert bad_hits, f"{rule} did not fire on {bad}"
    ok_hits = lint_file(CORPUS / ok)
    assert ok_hits == [], (f"conforming snippet {ok} not clean:\n"
                           + "\n".join(str(f) for f in ok_hits))


def test_r001_flags_both_function_and_module_loop():
    lines = {f.line for f in lint_file(CORPUS / "r001_bad.py")
             if f.rule == "R001"}
    assert len(lines) == 2


def test_r002_flags_scan_carried_function():
    msgs = [f.message for f in lint_file(CORPUS / "r002_bad.py")
            if f.rule == "R002"]
    assert any("scan_body" in m for m in msgs)
    assert any("time.perf_counter" in m for m in msgs)


def test_r004_reports_missing_hook_and_partial_mesh_set():
    msgs = [f.message for f in lint_file(CORPUS / "r004_bad.py")
            if f.rule == "R004"]
    assert any("extract" in m and "half_baked" in m for m in msgs)
    assert any("mesh" in m and "mesh_partial" in m for m in msgs)


def test_r006_flags_hardcoded_and_missing_interpret():
    hits = [f for f in lint_file(CORPUS / "r006_bad.py")
            if f.rule == "R006"]
    assert len(hits) == 2


@pytest.mark.parametrize("name", ["r001_suppressed.py", "r007_suppressed.py"])
def test_inline_suppression_silences_rule(name):
    assert lint_file(CORPUS / name) == []


def test_finding_renders_path_line_rule():
    f = Finding("R001", "src/x.py", 3, 5, "boom")
    assert str(f) == "src/x.py:3:5: R001 boom"


# ----------------------------------------------------------- lock rules
def test_lock_checker_fires_all_three_rules_on_bad_pipeline():
    findings = check_locks(SourceFile(CORPUS / "locks_bad.py"))
    assert {f.rule for f in findings} == {"L001", "L002", "L003"}
    l001 = [f for f in findings if f.rule == "L001"]
    # both unlocked shared writes in submit() are named
    assert len(l001) == 2
    assert all("submit" in f.message for f in l001)


def test_lock_checker_clean_on_good_pipeline():
    assert check_locks(SourceFile(CORPUS / "locks_ok.py")) == []


# ------------------------------------------------------- live repo gate
def test_live_repo_is_clean():
    findings = lint_paths()
    assert findings == [], ("reprolint findings on the live repo:\n"
                            + "\n".join(str(f) for f in findings))


# ------------------------------------------------------------------ CLI
@pytest.mark.parametrize("bad", [c[1] for c in RULE_CASES]
                         + ["locks_bad.py"])
def test_cli_nonzero_on_every_violation_snippet(bad, capsys):
    assert lint_main([str(CORPUS / bad)]) == 1
    assert "finding" in capsys.readouterr().out


def test_cli_zero_on_conforming_snippets(capsys):
    assert lint_main([str(CORPUS / c[2]) for c in RULE_CASES]
                     + [str(CORPUS / "locks_ok.py")]) == 0
    assert "clean" in capsys.readouterr().out


def test_cli_rule_selection(capsys):
    # with only R006 selected, an R001 violation must pass
    assert lint_main(["--rules", "R006", "--no-locks",
                      str(CORPUS / "r001_bad.py")]) == 0


# ------------------------------------------------------------ tracecheck
def test_tracecheck_attributes_deliberate_retrace_to_call_site():
    f = jax.jit(lambda x: x * 2 + 1)
    x = jnp.arange(4.0)
    with pytest.raises(TraceError) as ei:
        with tracecheck(steady_state=True):
            f(x)  # deliberate: first trace lands inside the window
    msg = str(ei.value)
    assert "test_analysis_lint.py" in msg, msg
    assert "retrace" in msg


def test_tracecheck_quiet_on_cached_calls():
    g = jax.jit(lambda x: x - 1)
    x = jnp.arange(3.0)
    g(x)  # warm OUTSIDE the window
    with tracecheck(steady_state=True):
        g(x)
        g(x)


def test_tracecheck_records_events_with_signature():
    h = jax.jit(lambda x: x + 2)
    x = jnp.arange(5.0)
    with tracecheck() as tc:
        h(x)
    evs = tc.traces()
    assert evs, "no trace events recorded"
    assert any(e.signature for e in evs) or evs
    assert "trace event" in tc.summary()
    assert all(e.line > 0 for e in evs)


def test_tracecheck_allow_patterns():
    k = jax.jit(lambda x: x * 3)
    x = jnp.arange(2.0)
    with tracecheck(steady_state=True, allow=("*",)):
        k(x)  # every trace allowed: must not raise
