# Standalone CI smoke scripts — invoked as files (python scripts/smokes/x.py)
# by scripts/ci.sh and .github/workflows/ci.yml, never imported.
