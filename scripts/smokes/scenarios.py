"""System-mode scenarios smoke: the three system classes — dense square,
least-squares, and block-sparse — end-to-end through the unified API on
BOTH backends (4 forced host devices, 2x2 data x model mesh), plus the
streaming mode: solve_stream drives 100 perturbed-b requests through the
sync and async servers with zero steady-state retraces and warm hits on
every warm_rhs_ok batch after the first."""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=4")

import time  # noqa: E402

import _path  # noqa: F401

import jax  # noqa: E402

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402

from repro import solvers  # noqa: E402
from repro.data import linsys  # noqa: E402
from repro.launch.mesh import make_compat_mesh  # noqa: E402
from repro.solvers import (AsyncLinsysServer, CapabilityError,  # noqa: E402
                           FactorStore, LinsysServer, solve_stream)

N_REQ = 100


def _rel(x, ref):
    return float(np.linalg.norm(np.asarray(x) - np.asarray(ref))
                 / np.linalg.norm(np.asarray(ref)))


def sparse_scenario(mesh):
    sys_ = linsys.banded_system(n=256, m=4, bandwidth=8, seed=0)
    assert sys_.is_sparse and sys_.sparsity > 0.8
    for name in ("apc", "cimmino", "dgd"):
        s = solvers.get(name)
        prm = s.resolve_params(sys_)
        r_sp = s.solve(sys_, iters=150, **prm)
        r_dn = s.solve(sys_.densified(), iters=150, **prm)
        assert np.allclose(np.asarray(r_sp.residuals),
                           np.asarray(r_dn.residuals),
                           rtol=1e-6, atol=1e-12), name
        r_mesh = s.solve(sys_, iters=150,
                         plan=solvers.ExecutionPlan(backend="mesh",
                                                    mesh=mesh), **prm)
        assert np.allclose(np.asarray(r_mesh.x), np.asarray(r_sp.x),
                           rtol=1e-8, atol=1e-10), name
    try:
        solvers.get("pdhbm").solve(sys_, iters=5)
    except CapabilityError:
        pass
    else:
        raise AssertionError("pdhbm accepted a sparse system")
    return f"sparse OK ({sys_.sparsity:.0%} zero, local+mesh parity)"


def ls_scenario(mesh):
    sys_ = linsys.tall_gaussian(N=320, n=160, m=4, seed=0, noise=0.05)
    assert sys_.mode == "least_squares"
    A, b = map(np.asarray, sys_.dense())
    x_ls, *_ = np.linalg.lstsq(A, b, rcond=None)
    for name in ("dgd", "dhbm"):
        s = solvers.get(name)
        prm = s.resolve_params(sys_)
        for plan in (solvers.ExecutionPlan(),
                     solvers.ExecutionPlan(backend="mesh", mesh=mesh)):
            r = s.solve(sys_, iters=800, plan=plan, **prm)
            assert _rel(r.x, x_ls) < 1e-6, (name, plan.backend)
            assert r.residuals[-1] < 1e-8, (name, plan.backend)
    # Cimmino's Gram-weighted fixed point, against its own reference
    s = solvers.get("cimmino")
    r = s.solve(sys_, iters=800, **s.resolve_params(sys_))
    assert _rel(r.x, s.ls_reference(sys_)) < 1e-6
    try:
        solvers.get("apc").solve(sys_, iters=5)
    except CapabilityError:
        pass
    else:
        raise AssertionError("apc accepted a least-squares system")
    return "least-squares OK (lstsq parity, local+mesh)"


def stream_scenario():
    sys_ = linsys.conditioned_gaussian(n=64, m=4, cond=10.0, seed=0)
    rng = np.random.default_rng(0)
    b0 = rng.standard_normal(64)
    msgs = []
    for tag, srv in (
        ("sync", LinsysServer(FactorStore(), solver="dhbm", iters=150,
                              batch=1, warm_start=True)),
        ("async", AsyncLinsysServer(FactorStore(), solver="dhbm",
                                    iters=150, batch=1, warm_start=True)),
    ):
        fp = srv.register(sys_)
        stream = [(fp, b0 + 1e-3 * rng.standard_normal(64))
                  for _ in range(N_REQ)]
        # prime the cold AND warm executor paths (one batch each), then
        # the steady-state jit cache must not grow
        solve_stream(srv, stream[:2])
        cache0 = srv.jit_cache_size()
        rep = solve_stream(srv, stream[2:])
        if hasattr(srv, "close"):
            srv.close()
        assert len(rep.served) == N_REQ - 2, tag
        assert rep.warm_batches == rep.batches, tag   # every batch warm
        assert all(r.warm for r in rep.served), tag
        assert all(r.residual < 1e-8 for r in rep.served), tag
        cache1 = srv.jit_cache_size()
        assert cache0 < 0 or cache1 == cache0, \
            f"{tag}: steady-state retrace, jit cache {cache0} -> {cache1}"
        msgs.append(f"{tag} warm rate {rep.warm_hit_rate:.0%}")
    return f"stream OK ({N_REQ} perturbed-b requests, " + ", ".join(msgs) + ")"


def main():
    t0 = time.time()
    assert len(jax.devices()) == 4, jax.devices()
    mesh = make_compat_mesh((2, 2), ("data", "model"))
    lines = [sparse_scenario(mesh), ls_scenario(mesh), stream_scenario()]
    for ln in lines:
        print("  " + ln)
    print(f"scenarios smoke OK in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
