"""Async-serving smoke: AsyncLinsysServer pipelines a 2-system open-loop
request stream — every residual under tol, zero sheds at a feasible
rate, zero steady-state retraces (attributed via tracecheck: a failure
names the retracing call site), and the SLO report populated."""
import time

import _path  # noqa: F401

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402

from repro.analysis import tracecheck  # noqa: E402
from repro.data import linsys  # noqa: E402
from repro.solvers import AsyncLinsysServer, FactorStore, Shed  # noqa: E402


def main():
    t0 = time.time()
    N_REQ = 12
    s1 = linsys.conditioned_gaussian(n=64, m=4, cond=10.0, seed=0)
    s2 = linsys.conditioned_gaussian(n=64, m=4, cond=10.0, seed=1)
    store = FactorStore()
    srv = AsyncLinsysServer(store, solver="apc", iters=600, tol=1e-6,
                            batch=2, pipeline_depth=2, admit_capacity=64)
    fps = [srv.register(s1), srv.register(s2)]
    rng = np.random.default_rng(0)

    with srv:
        # prime off the clock: first batch per system pays prepare+compile
        prime = [srv.submit(fps[i % 2], rng.standard_normal(64))
                 for i in range(4)]
        for t in prime:
            t.result(timeout=300)
        srv.reset_metrics()

        # steady state under tracecheck: a retrace anywhere in the
        # pipeline fails here NAMING the offending call site
        with tracecheck(steady_state=True):
            tickets = [srv.submit(fps[i % 2], rng.standard_normal(64))
                       for i in range(N_REQ)]
            results = [t.result(timeout=300) for t in tickets]
        cache1 = srv.jit_cache_size()

    assert [r.rid for r in results] == [t.rid for t in tickets]
    sheds = [r for r in results if isinstance(r, Shed)]
    assert not sheds, f"unexpected sheds at a feasible rate: {sheds}"
    bad = [r.residual for r in results if not r.residual < 1e-6]
    assert not bad, f"residuals above tol: {bad}"
    rep = srv.latency_report()
    assert rep["count"] == N_REQ and rep["p99_ms"] > 0
    assert srv.stats.served == N_REQ and srv.stats.shed == 0
    print(f"serve_async smoke OK: {N_REQ} requests over 2 systems, "
          f"p50/p99 {rep['p50_ms']:.0f}/{rep['p99_ms']:.0f} ms, "
          f"{srv.stats.batches} batches, jit cache {cache1}, "
          f"store {store.stats} in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
