"""Make the smokes runnable with or without PYTHONPATH=src: importing
this module prepends the repo's src/ to sys.path (idempotent)."""
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))
_SRC = os.path.join(REPO, "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)
