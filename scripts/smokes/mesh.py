"""Mesh-backend smoke: every registered solver sharded on a forced
4-host-device 2x2 (data x model) mesh matches the local driver."""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=4")

import time  # noqa: E402

import _path  # noqa: F401

import jax  # noqa: E402

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402

from repro import solvers  # noqa: E402
from repro.data import linsys  # noqa: E402
from repro.launch.mesh import make_compat_mesh  # noqa: E402


def main():
    t0 = time.time()
    assert len(jax.devices()) == 4, jax.devices()
    sys_ = linsys.conditioned_gaussian(n=64, m=4, cond=10.0, seed=3)
    mesh = make_compat_mesh((2, 2), ("data", "model"))
    for name in solvers.available():
        s = solvers.get(name)
        prm = s.resolve_params(sys_)
        rl = s.solve(sys_, iters=120, **prm)
        rm = s.solve(sys_, iters=120,
                     plan=solvers.ExecutionPlan(backend="mesh", mesh=mesh),
                     **prm)
        assert np.allclose(np.asarray(rm.residuals),
                           np.asarray(rl.residuals),
                           rtol=1e-6, atol=1e-12), name
        assert rm.errors is not None and rm.residuals.shape == (120,), name
    print(f"mesh smoke OK: {solvers.available()} sharded on {mesh} "
          f"in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
