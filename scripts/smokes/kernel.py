"""Kernel smoke: EVERY Pallas path of the fused projection engine.

Covers, against the unfused/pure-jnp references:

  * raw ops — fused block_projection (single + multi-RHS), the split
    proj_gather/proj_scatter pair, and the Cimmino gather/scatter pair,
    including a non-multiple-of-128 n and a p=1 edge block;
  * sparse ops — the compressed-support ``sparse_proj_update`` /
    ``sparse_cimmino_update`` pair vs the einsum oracles with the engine
    pinned fused, then end-to-end silent sparse dispatch (local + mesh,
    fused-residual history parity) and a ``precision="mixed"`` solve;
  * solver paths — apc / consensus / cimmino with ``use_kernel=True`` on
    the local AND mesh backends (forced 4-host-device 2x2 data x model
    mesh, so the column-sharded gather/psum/scatter composition runs),
    plus the fused multi-RHS ``solve_many``;
  * serving — a ``LinsysServer(use_kernel=True)`` batch at zero
    steady-state retraces;
  * autotune — the BN cache fills, and ``REPRO_KERNEL_BN`` pins.

Interpret vs compiled: the smoke honors the ambient
``REPRO_PALLAS_INTERPRET`` (ci.sh runs it with ``=1`` every push; lanes
where Pallas lowering is available re-run it with ``=0`` so lowering
regressions surface — exactly the use ``default_interpret`` promises).
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=4")
os.environ.setdefault("REPRO_PALLAS_INTERPRET", "1")

import time  # noqa: E402

import _path  # noqa: F401

import jax  # noqa: E402

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro import solvers  # noqa: E402
from repro.data import linsys  # noqa: E402
from repro.kernels import block_projection as bp  # noqa: E402
from repro.kernels import ops, ref  # noqa: E402
from repro.launch.mesh import make_compat_mesh  # noqa: E402
from repro.solvers import FactorStore, LinsysServer  # noqa: E402

PROJ = ("apc", "consensus", "cimmino")


def _mk(p, n, k, dtype, seed=0):
    rng = np.random.default_rng(seed)
    A = jnp.asarray(rng.standard_normal((p, n)), dtype)
    G = np.asarray(A, np.float64) @ np.asarray(A, np.float64).T
    B = jnp.asarray(np.linalg.solve(G, np.asarray(A, np.float64)), dtype).T
    shp = (n,) if k == 1 else (k, n)
    x = jnp.asarray(rng.standard_normal(shp), dtype)
    xb = jnp.asarray(rng.standard_normal(shp), dtype)
    b = jnp.asarray(rng.standard_normal((p,) if k == 1 else (k, p)), dtype)
    return A, B, x, xb, b


def smoke_raw_ops():
    for p, n, k, dtype, tol in ((8, 256, 1, jnp.float32, 1e-4),
                                (7, 130, 5, jnp.float64, 1e-10),
                                (1, 128, 16, jnp.float64, 1e-10)):
        A, B, x, xb, b = _mk(p, n, k, dtype)
        y = ops.block_projection(A, B, x, xb, 1.2)
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(ref.block_projection_ref(A, B, x, xb,
                                                               1.2)),
            rtol=tol, atol=tol)
        u = ops.proj_gather(A, x, xb)
        np.testing.assert_allclose(
            np.asarray(u), np.asarray(ref.apc_gather_ref(A, x, xb)),
            rtol=tol, atol=tol)
        y2 = ops.proj_scatter(B, x, xb, u, 0.8)
        np.testing.assert_allclose(
            np.asarray(y2),
            np.asarray(ref.apc_scatter_ref(B, x, xb, u, 0.8)),
            rtol=tol, atol=tol)
        r = ops.cimmino_update(A, B, b, xb)
        np.testing.assert_allclose(
            np.asarray(r), np.asarray(ref.cimmino_update_ref(A, B, b, xb)),
            rtol=tol, atol=tol * 10)
    assert len(ops.bn_cache()) > 0, "BN autotune cache never filled"


def smoke_solver_paths():
    assert len(jax.devices()) == 4, jax.devices()
    sys_ = linsys.conditioned_gaussian(n=96, m=4, cond=10.0, seed=3)
    mesh = make_compat_mesh((2, 2), ("data", "model"))
    Bk = np.random.default_rng(4).standard_normal((5, sys_.N))
    for name in PROJ:
        s = solvers.get(name)
        prm = s.resolve_params(sys_)
        r0 = s.solve(sys_, iters=100, **prm)
        for tag, plan in (
                ("local", solvers.ExecutionPlan(kernel=True)),
                ("mesh", solvers.ExecutionPlan(kernel=True, backend="mesh",
                                               mesh=mesh))):
            rk = s.solve(sys_, iters=100, plan=plan, **prm)
            assert np.allclose(np.asarray(rk.residuals),
                               np.asarray(r0.residuals),
                               rtol=1e-6, atol=1e-12), (name, tag)
        m0 = s.solve_many(sys_, Bk, iters=100, **prm)
        mk = s.solve_many(sys_, Bk, iters=100,
                          plan=solvers.ExecutionPlan(kernel=True), **prm)
        assert np.allclose(np.asarray(mk.residuals),
                           np.asarray(m0.residuals),
                           rtol=1e-6, atol=1e-12), name


def smoke_sparse_paths():
    """Sparse fused pair + mixed precision (PR 9): raw ops against the
    einsum oracles with the engine PINNED fused (so the autotune cannot
    route around the kernels), then end-to-end dispatch parity."""
    rng = np.random.default_rng(6)
    for p, w, n, k, dtype, tol in ((8, 128, 256, 1, jnp.float32, 1e-4),
                                   (7, 61, 130, 5, jnp.float64, 1e-10)):
        vals = jnp.asarray(rng.standard_normal((p, w)), dtype)
        cols = jnp.asarray(rng.choice(n, size=w, replace=False), jnp.int32)
        bvals = jnp.asarray(rng.standard_normal((w, p)), dtype)
        shp = (n,) if k == 1 else (k, n)
        x = jnp.asarray(rng.standard_normal(shp), dtype)
        xb = jnp.asarray(rng.standard_normal(shp), dtype)
        b = jnp.asarray(rng.standard_normal((p,) if k == 1 else (k, p)),
                        dtype)
        prev = os.environ.get(ops.ENGINE_ENV)
        os.environ[ops.ENGINE_ENV] = "fused"
        try:
            y, u = ops.sparse_proj_update(vals, cols, bvals, x, xb, 0.9)
            yr, ur = ref.sparse_proj_update_ref(vals, cols, bvals, x, xb,
                                                0.9)
            np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                                       rtol=tol, atol=tol)
            np.testing.assert_allclose(np.asarray(u), np.asarray(ur),
                                       rtol=tol, atol=tol)
            r, uc = ops.sparse_cimmino_update(vals, cols, bvals, b, xb)
            rr, ucr = ref.sparse_cimmino_update_ref(vals, cols, bvals, b,
                                                    xb)
            np.testing.assert_allclose(np.asarray(r), np.asarray(rr),
                                       rtol=tol, atol=tol)
            np.testing.assert_allclose(np.asarray(uc), np.asarray(ucr),
                                       rtol=tol, atol=tol)
        finally:
            if prev is None:
                os.environ.pop(ops.ENGINE_ENV, None)
            else:
                os.environ[ops.ENGINE_ENV] = prev

    # end-to-end: silent sparse dispatch + fused-residual history parity
    import warnings
    sys_ = linsys.banded_system(n=192, m=4, bandwidth=6, seed=0)
    mesh = make_compat_mesh((2, 2), ("data", "model"))
    for name in ("apc", "cimmino"):
        s = solvers.get(name)
        prm = s.resolve_params(sys_)
        r0 = s.solve(sys_, iters=80, **prm)
        with warnings.catch_warnings():
            warnings.simplefilter("error", RuntimeWarning)
            rk = s.solve(sys_, iters=80,
                         plan=solvers.ExecutionPlan(kernel=True), **prm)
            rm = s.solve(sys_, iters=80,
                         plan=solvers.ExecutionPlan(kernel=True,
                                                    backend="mesh",
                                                    mesh=mesh), **prm)
        for tag, r in (("local", rk), ("mesh", rm)):
            assert np.allclose(np.asarray(r.residuals),
                               np.asarray(r0.residuals),
                               rtol=1e-4, atol=2e-6), (name, tag)
        # mixed precision: bf16 tile streams must stay finite and track
        # the f32 history within the bf16 envelope
        rx = s.solve(sys_, iters=80,
                     plan=solvers.ExecutionPlan(kernel=True,
                                                precision="mixed"), **prm)
        res = np.asarray(rx.residuals)
        assert np.all(np.isfinite(res)), name
        assert np.allclose(res, np.asarray(r0.residuals),
                           rtol=0.5, atol=5e-2), (name, float(res[-1]))


def smoke_serving():
    sys_ = linsys.conditioned_gaussian(n=96, m=4, cond=10.0, seed=3)
    store = FactorStore()
    srv = LinsysServer(store, solver="apc", iters=300, batch=4,
                       use_kernel=True)
    fp = srv.register(sys_)
    rng = np.random.default_rng(0)
    sizes = []
    for _ in range(3):
        for _ in range(4):
            srv.submit(fp, rng.standard_normal(sys_.N))
        out = srv.step()
        assert all(r.residual < 1e-6 for r in out), [r.residual for r in out]
        sizes.append(srv.jit_cache_size())
    tail = sizes[1:]
    assert (-1 in tail) or len(set(tail)) == 1, sizes
    assert store.stats.misses == 1 and store.stats.hits >= 2, store.stats


def main():
    t0 = time.time()
    mode = ("interpret" if bp.default_interpret() else "COMPILED")
    smoke_raw_ops()
    smoke_solver_paths()
    smoke_sparse_paths()
    smoke_serving()
    print(f"kernel smoke OK ({mode}, "
          f"REPRO_PALLAS_INTERPRET={os.environ['REPRO_PALLAS_INTERPRET']}): "
          f"raw ops + sparse/mixed + 3 solvers x local/mesh/solve_many + "
          f"serving, bn cache {ops.bn_cache()} in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
