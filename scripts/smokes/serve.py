"""Serving smoke: LinsysServer drains a 2-system request stream with
factor-store amortization (>= N-2 hits) and every residual under tol."""
import time

import _path  # noqa: F401

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402

from repro.data import linsys  # noqa: E402
from repro.solvers import FactorStore, LinsysServer  # noqa: E402


def main():
    t0 = time.time()
    N_REQ = 8
    s1 = linsys.conditioned_gaussian(n=64, m=4, cond=10.0, seed=0)
    s2 = linsys.conditioned_gaussian(n=64, m=4, cond=10.0, seed=1)
    store = FactorStore()
    # batch=1: every request is its own store lookup, so exactly the first
    # request per system may miss
    srv = LinsysServer(store, solver="apc", iters=600, tol=1e-6, batch=1)
    fps = [srv.register(s1), srv.register(s2)]
    rng = np.random.default_rng(0)
    for i in range(N_REQ):
        srv.submit(fps[i % 2], rng.standard_normal(64))
    out = srv.drain()
    assert len(out) == N_REQ and [r.rid for r in out] == list(range(N_REQ))
    bad = [r.residual for r in out if not r.residual < 1e-6]
    assert not bad, f"residuals above tol: {bad}"
    assert store.stats.total_hits >= N_REQ - 2, store.stats
    assert srv.stats.served == N_REQ and srv.stats.padded == 0
    print(f"serve smoke OK: {N_REQ} requests over 2 systems, "
          f"store {store.stats}, {srv.stats.executor_builds} executor "
          f"build(s) in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
