"""Elastic smoke: ElasticRuntime survives kill -> rejoin -> taskmaster
loss: the death re-lowers the schedule exactly (oracle-equal history),
the recovery rebuilds every block factor from the store's disk tier
(counted as reuse), and the solve still converges below tol."""
import tempfile
import time

import _path  # noqa: F401

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402

from repro import solvers  # noqa: E402
from repro.data import linsys  # noqa: E402
from repro.runtime.fault import HeartbeatMonitor  # noqa: E402
from repro.solvers import ExecutionPlan, FactorStore  # noqa: E402

TOL = 1e-8


def main():
    t0 = time.time()
    sys_ = linsys.conditioned_gaussian(n=64, m=4, cond=10.0, seed=3)
    s = solvers.get("apc")
    prm = s.resolve_params(sys_)
    oracle = s.solve(sys_, iters=150, tol=TOL, plan=ExecutionPlan(), **prm)

    with tempfile.TemporaryDirectory() as tmp:
        store_dir, ck_dir = tmp + "/store", tmp + "/ck"
        mon = HeartbeatMonitor(n_workers=sys_.m)
        rt = solvers.ElasticRuntime(
            s, sys_,
            plan=ExecutionPlan(redundancy=2,
                               store=FactorStore(directory=store_dir)),
            monitor=mon, segment=25, tol=TOL, checkpoint_dir=ck_dir, **prm)
        r1 = rt.run(iters=50)
        mon.mark_dead(2)                       # kill mid-solve
        r2 = rt.run(iters=25)
        mon.rejoin(2, resynced=True)           # returnee: pure reassignment
        r3 = rt.run(iters=25)
        assert r3.relowerings == 1 and r3.repartitions == 0, \
            (r3.relowerings, r3.repartitions)
        res = np.concatenate([np.asarray(r.residuals)
                              for r in (r1, r2, r3)])
        assert np.allclose(res, np.asarray(oracle.residuals)[:100],
                           rtol=1e-6, atol=1e-12)
        del rt                                 # the taskmaster dies

        rt2 = solvers.ElasticRuntime.recover(
            s, sys_, ck_dir,
            plan=ExecutionPlan(redundancy=2,
                               store=FactorStore(directory=store_dir)),
            monitor=HeartbeatMonitor(n_workers=sys_.m), **prm)
        assert rt2.reused_blocks >= 1, rt2.reused_blocks
        assert rt2.reused_blocks == sys_.m and rt2.prepared_blocks == 0
        rep = rt2.run(iters=50)
        assert rep.iters == 150
        assert float(rep.residuals[-1]) < TOL, float(rep.residuals[-1])
        np.testing.assert_allclose(np.asarray(rep.x),
                                   np.asarray(oracle.x),
                                   rtol=1e-6, atol=1e-10)
    print(f"elastic smoke OK: death re-lowered exactly, recovery reused "
          f"{rt2.reused_blocks}/{sys_.m} block factors from disk, final "
          f"residual {float(rep.residuals[-1]):.1e} < {TOL} in "
          f"{time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
