"""Solver-registry smoke: all eight methods resolve and round-trip the
unified lifecycle (invoked by scripts/ci.sh and the hosted CI workflow)."""
import time

import _path  # noqa: F401  (sys.path setup)

import jax

jax.config.update("jax_enable_x64", True)

from repro import solvers  # noqa: E402
from repro.data import linsys  # noqa: E402


def main():
    t0 = time.time()
    sys_ = linsys.conditioned_gaussian(n=128, m=4, cond=20.0, seed=0)
    names = solvers.available()
    required = {"apc", "cimmino", "consensus", "dgd", "dhbm", "dnag",
                "madmm", "pdhbm"}
    missing = required - set(names)
    assert not missing, f"missing solvers: {missing}"
    for n in names:
        s = solvers.get(n)                       # registry lookup
        r = s.solve(sys_, iters=30)              # lifecycle round-trip
        assert r.name == n and r.x.shape == (sys_.n,), n
    print(f"registry smoke OK: {names} in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
