"""Straggler smoke: apc r=2 under a rotating straggler is EXACT (equal to
the no-failure run) on the local backend and a forced 2x2 mesh."""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=4")

import time  # noqa: E402

import _path  # noqa: F401

import jax  # noqa: E402

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402

from repro import solvers  # noqa: E402
from repro.data import linsys  # noqa: E402
from repro.launch.mesh import make_compat_mesh  # noqa: E402


def main():
    t0 = time.time()
    assert len(jax.devices()) == 4, jax.devices()
    sys_ = linsys.conditioned_gaussian(n=64, m=4, cond=10.0, seed=3)
    mesh = make_compat_mesh((2, 2), ("data", "model"))
    sched = lambda t: np.array([i != (t % 4) for i in range(4)])
    s = solvers.get("apc")
    prm = s.resolve_params(sys_)
    r0 = s.solve(sys_, iters=120, **prm)                       # no failures
    rl = s.solve(sys_, iters=120,
                 plan=solvers.ExecutionPlan(redundancy=2,
                                            alive_schedule=sched), **prm)
    rm = s.solve(sys_, iters=120,
                 plan=solvers.ExecutionPlan(redundancy=2,
                                            alive_schedule=sched,
                                            backend="mesh", mesh=mesh),
                 **prm)
    for r, tag in ((rl, "local"), (rm, "mesh")):
        assert np.allclose(np.asarray(r.residuals),
                           np.asarray(r0.residuals),
                           rtol=1e-6, atol=1e-12), tag
        assert np.allclose(np.asarray(r.x), np.asarray(r0.x),
                           rtol=1e-8, atol=1e-10), tag
    print(f"straggler smoke OK: apc r=2 exact under a rotating straggler "
          f"on local and {mesh} in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
