#!/usr/bin/env bash
# Tier-1 CI: fast test suite + solver-registry smoke.
#
#     bash scripts/ci.sh
#
# The "not slow" selection skips the subprocess/system tests (run the full
# suite with `PYTHONPATH=src python -m pytest -q` before a release).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== pytest (tier 1, -m 'not slow') =="
python -m pytest -q -m "not slow"

echo "== solver registry smoke =="
python - <<'EOF'
import time
import jax
jax.config.update("jax_enable_x64", True)
from repro import solvers
from repro.data import linsys

t0 = time.time()
sys_ = linsys.conditioned_gaussian(n=128, m=4, cond=20.0, seed=0)
names = solvers.available()
required = {"apc", "cimmino", "consensus", "dgd", "dhbm", "dnag", "madmm",
            "pdhbm"}
missing = required - set(names)
assert not missing, f"missing solvers: {missing}"
for n in names:
    s = solvers.get(n)                       # registry lookup
    r = s.solve(sys_, iters=30)              # lifecycle round-trip
    assert r.name == n and r.x.shape == (sys_.n,), n
print(f"registry smoke OK: {names} in {time.time()-t0:.1f}s")
EOF

echo "== mesh-backend smoke (4 forced host devices, 2x2 data x model) =="
XLA_FLAGS=--xla_force_host_platform_device_count=4 python - <<'EOF'
import time
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np
from repro import solvers
from repro.data import linsys
from repro.launch.mesh import make_compat_mesh

t0 = time.time()
assert len(jax.devices()) == 4, jax.devices()
sys_ = linsys.conditioned_gaussian(n=64, m=4, cond=10.0, seed=3)
mesh = make_compat_mesh((2, 2), ("data", "model"))
for name in solvers.available():
    s = solvers.get(name)
    prm = s.resolve_params(sys_)
    rl = s.solve(sys_, iters=120, **prm)
    rm = s.solve(sys_, iters=120, backend="mesh", mesh=mesh, **prm)
    assert np.allclose(np.asarray(rm.residuals), np.asarray(rl.residuals),
                       rtol=1e-6, atol=1e-12), name
    assert rm.errors is not None and rm.residuals.shape == (120,), name
print(f"mesh smoke OK: {solvers.available()} sharded on {mesh} "
      f"in {time.time()-t0:.1f}s")
EOF

echo "== serve smoke (LinsysServer: 2 systems, factor-store amortization) =="
python - <<'EOF'
import time
import numpy as np
import jax
jax.config.update("jax_enable_x64", True)
from repro.data import linsys
from repro.solvers import FactorStore, LinsysServer

t0 = time.time()
N_REQ = 8
s1 = linsys.conditioned_gaussian(n=64, m=4, cond=10.0, seed=0)
s2 = linsys.conditioned_gaussian(n=64, m=4, cond=10.0, seed=1)
store = FactorStore()
# batch=1: every request is its own store lookup, so exactly the first
# request per system may miss
srv = LinsysServer(store, solver="apc", iters=600, tol=1e-6, batch=1)
fps = [srv.register(s1), srv.register(s2)]
rng = np.random.default_rng(0)
for i in range(N_REQ):
    srv.submit(fps[i % 2], rng.standard_normal(64))
out = srv.drain()
assert len(out) == N_REQ and [r.rid for r in out] == list(range(N_REQ))
bad = [r.residual for r in out if not r.residual < 1e-6]
assert not bad, f"residuals above tol: {bad}"
assert store.stats.total_hits >= N_REQ - 2, store.stats
assert srv.stats.served == N_REQ and srv.stats.padded == 0
print(f"serve smoke OK: {N_REQ} requests over 2 systems, "
      f"store {store.stats}, {srv.stats.executor_builds} executor "
      f"build(s) in {time.time()-t0:.1f}s")
EOF

echo "== straggler smoke (r=2, rotating straggler, 4 forced host devices) =="
XLA_FLAGS=--xla_force_host_platform_device_count=4 python - <<'EOF'
import time
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np
from repro import solvers
from repro.data import linsys
from repro.launch.mesh import make_compat_mesh

t0 = time.time()
assert len(jax.devices()) == 4, jax.devices()
sys_ = linsys.conditioned_gaussian(n=64, m=4, cond=10.0, seed=3)
mesh = make_compat_mesh((2, 2), ("data", "model"))
sched = lambda t: np.array([i != (t % 4) for i in range(4)])
s = solvers.get("apc")
prm = s.resolve_params(sys_)
r0 = s.solve(sys_, iters=120, **prm)                       # no failures
rl = s.solve(sys_, iters=120, redundancy=2, alive_schedule=sched, **prm)
rm = s.solve(sys_, iters=120, redundancy=2, alive_schedule=sched,
             backend="mesh", mesh=mesh, **prm)
for r, tag in ((rl, "local"), (rm, "mesh")):
    assert np.allclose(np.asarray(r.residuals), np.asarray(r0.residuals),
                       rtol=1e-6, atol=1e-12), tag
    assert np.allclose(np.asarray(r.x), np.asarray(r0.x),
                       rtol=1e-8, atol=1e-10), tag
print(f"straggler smoke OK: apc r=2 exact under a rotating straggler on "
      f"local and {mesh} in {time.time()-t0:.1f}s")
EOF
echo "CI OK"
