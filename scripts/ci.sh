#!/usr/bin/env bash
# Tier-1 CI: fast test suite + solver-registry smoke.
#
#     bash scripts/ci.sh
#
# The "not slow" selection skips the subprocess/system tests (run the full
# suite with `PYTHONPATH=src python -m pytest -q` before a release).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== pytest (tier 1, -m 'not slow') =="
python -m pytest -q -m "not slow"

echo "== solver registry smoke =="
python - <<'EOF'
import time
import jax
jax.config.update("jax_enable_x64", True)
from repro import solvers
from repro.data import linsys

t0 = time.time()
sys_ = linsys.conditioned_gaussian(n=128, m=4, cond=20.0, seed=0)
names = solvers.available()
required = {"apc", "cimmino", "consensus", "dgd", "dhbm", "dnag", "madmm",
            "pdhbm"}
missing = required - set(names)
assert not missing, f"missing solvers: {missing}"
for n in names:
    s = solvers.get(n)                       # registry lookup
    r = s.solve(sys_, iters=30)              # lifecycle round-trip
    assert r.name == n and r.x.shape == (sys_.n,), n
print(f"registry smoke OK: {names} in {time.time()-t0:.1f}s")
EOF
echo "CI OK"
