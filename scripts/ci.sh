#!/usr/bin/env bash
# Tier-1 CI: fast test suite + the smoke scripts under scripts/smokes/.
#
#     bash scripts/ci.sh
#
# The same smokes are invoked by .github/workflows/ci.yml (no heredoc
# drift: this file and the workflow share the scripts/smokes/*.py files).
# The "not slow" selection skips the subprocess/system tests — the full
# suite is `PYTHONPATH=src python -m pytest -q` (the workflow's nightly /
# `ci:full`-label lane runs it).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== lint (ruff + reprolint contract checks) =="
bash scripts/lint.sh

echo "== pytest (tier 1, -m 'not slow') =="
python -m pytest -q -m "not slow"

echo "== solver registry smoke =="
python scripts/smokes/registry.py

# the device-forcing smokes get XLA_FLAGS set EXPLICITLY (not just the
# scripts' setdefault fallback) so an ambient XLA_FLAGS — e.g. a debug
# --xla_dump_to — cannot silently drop the forced 4-device topology
FORCE4="--xla_force_host_platform_device_count=4"

echo "== mesh-backend smoke (4 forced host devices, 2x2 data x model) =="
XLA_FLAGS="$FORCE4" python scripts/smokes/mesh.py

echo "== serve smoke (LinsysServer: 2 systems, factor-store amortization) =="
python scripts/smokes/serve.py

echo "== serve_async smoke (AsyncLinsysServer: pipelined stream, SLO report) =="
python scripts/smokes/serve_async.py

echo "== scenarios smoke (sparse/LS/stream modes, local + 2x2 mesh) =="
XLA_FLAGS="$FORCE4" python scripts/smokes/scenarios.py

echo "== straggler smoke (r=2, rotating straggler, 4 forced host devices) =="
XLA_FLAGS="$FORCE4" python scripts/smokes/straggler.py

echo "== elastic smoke (kill -> rejoin -> taskmaster recovery, factor reuse) =="
python scripts/smokes/elastic.py

echo "== kernel smoke (every Pallas path, interpret mode) =="
XLA_FLAGS="$FORCE4" REPRO_PALLAS_INTERPRET=1 python scripts/smokes/kernel.py

# Lanes where Pallas lowering is available (real TPU runners) re-run the
# identical smoke force-compiled, so lowering regressions surface in CI —
# exactly the use kernels.block_projection.default_interpret documents.
if [[ "${REPRO_CI_COMPILE_LANE:-0}" == "1" ]]; then
  echo "== kernel smoke (force-compile pass, REPRO_PALLAS_INTERPRET=0) =="
  XLA_FLAGS="$FORCE4" REPRO_PALLAS_INTERPRET=0 python scripts/smokes/kernel.py
fi

echo "CI OK"
