#!/usr/bin/env python
"""Benchmark trend seed + regression gate for the hosted CI.

Runs the quick-mode benchmark pair —

  * ``benchmarks.periter.kernel_comparison``: per-iteration times of the
    fused Pallas engine vs the unfused step for the projection family,
    batch 1 vs batch 16;
  * ``benchmarks.serve_traffic.measure``: cold/warm serve latency and
    the jit-cache trajectory through ``LinsysServer``;

— and writes them machine-readable to BENCH_PR5.json so future PRs have
a trajectory to diff against.  Two invariants are GATED (non-zero exit):

  * zero steady-state retraces — the serve jit cache is constant across
    the tail batches;
  * kernel >= unfused at batch 16 for APC — the fused multi-RHS path
    must not regress below the path it replaces at serving batch sizes
    (on CPU lanes both run interpret/XLA side by side: the kernel wins
    because the pinv-augmented step eliminates the per-iteration Gram
    solves; on TPU the same gate covers the compiled kernels).

    PYTHONPATH=src python scripts/bench_ci.py --out BENCH_PR5.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for d in (REPO, os.path.join(REPO, "src")):
    if d not in sys.path:
        sys.path.insert(0, d)

import jax  # noqa: E402

jax.config.update("jax_enable_x64", True)

# Benchmark shapes (quick mode: the tier-1 lane runs this every push).
# p = n/m = 256 rows per worker on a single BN tile is the store-served
# worker block where the kernel's fused traffic + no-Gram-solve step is
# decisively ahead even in interpret mode; batch 16 is the serving batch.
PERITER = dict(n=512, m=2, batches=(1, 16), iters=30)
SERVE = dict(n=256, m=4, iters=100, warm_batches=6)
GATE_METHOD = "apc"
GATE_BATCH = 16


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_PR5.json",
                    help="where to write the benchmark trajectory record")
    ap.add_argument("--no-gate", action="store_true",
                    help="record only; do not fail on gate violations "
                         "(bootstrap / exotic hardware)")
    args = ap.parse_args(argv)

    from benchmarks import periter, serve_traffic
    from repro.kernels import block_projection as bp

    print(f"== bench_ci: periter kernel comparison {PERITER} ==")
    per = periter.kernel_comparison(**PERITER)
    for name, row in per["methods"].items():
        print(f"  {name:10s} b1  unfused {row['unfused_b1_us']:9.1f}us  "
              f"kernel {row['kernel_b1_us']:9.1f}us  "
              f"({row['kernel_speedup_b1']:.2f}x)")
        print(f"  {name:10s} b16 unfused {row['unfused_b16_us']:9.1f}us  "
              f"kernel {row['kernel_b16_us']:9.1f}us  "
              f"({row['kernel_speedup_b16']:.2f}x)")

    print(f"== bench_ci: serve_traffic {SERVE} ==")
    srv = serve_traffic.measure(**SERVE)
    print(f"  cold {srv['cold_s']*1e3:.1f} ms   warm {srv['warm_s']*1e3:.1f}"
          f" ms   ({srv['speedup']:.1f}x, {srv['rhs_per_s']:.1f} RHS/s, "
          f"jit cache {srv['jit_cache_tail']})")

    gate_speedup = per["methods"][GATE_METHOD][
        f"kernel_speedup_b{GATE_BATCH}"]
    gates = {
        # the fused path must not regress below the path it replaces
        "kernel_ge_unfused_b16": gate_speedup >= 1.0,
        # steady-state serving must never retrace
        "zero_retrace": bool(srv["zero_retrace"]),
    }
    record = {
        "schema": 1,
        "pr": 5,
        "backend": jax.default_backend(),
        "pallas_interpret": bp.default_interpret(),
        "gate": {"method": GATE_METHOD, "batch": GATE_BATCH,
                 "kernel_speedup": gate_speedup},
        "periter_kernel": per,
        "serve_traffic": srv,
        "gates": gates,
    }
    with open(args.out, "w") as f:
        json.dump(record, f, indent=2)
    print(f"wrote {args.out}")

    failed = [k for k, ok in gates.items() if not ok]
    if failed:
        msg = (f"bench gate FAILED: {failed} "
               f"(kernel speedup b{GATE_BATCH}={gate_speedup:.2f}x, "
               f"jit cache tail {srv['jit_cache_tail']})")
        if args.no_gate:
            print(f"WARNING (--no-gate): {msg}")
            return 0
        print(msg, file=sys.stderr)
        return 1
    print(f"bench gates OK: kernel {gate_speedup:.2f}x >= 1.0 at "
          f"batch {GATE_BATCH}, zero retraces")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
