#!/usr/bin/env python
"""Benchmark trend record + regression gates for the hosted CI.

Runs the quick-mode benchmark set —

  * ``benchmarks.periter.kernel_comparison``: per-iteration times for the
    projection family at batch 1 vs 16 along THREE paths — unfused,
    raw fused kernels (pinned via ``REPRO_KERNEL_ENGINE=fused``), and the
    dispatched path (``kops.use_fused`` engine autotune, measured here
    with ``REPRO_KERNEL_AUTOTUNE=1`` so the choice reflects this host);
  * ``benchmarks.serve_traffic.measure``: cold/warm serve latency and the
    jit-cache trajectory through ``LinsysServer``;
  * ``benchmarks.serve_traffic.traffic``: the open-loop Poisson harness at
    2x the sync loop's saturation throughput, sync vs async, plus an
    overload probe (tiny ``admit_capacity`` at an infinite rate) that
    must shed EXPLICITLY rather than queue unboundedly;

— and writes them machine-readable to BENCH_PR8.json.  Gates (non-zero
exit on violation):

  * ``zero_retrace`` / ``async_zero_retrace`` — steady-state serving
    never retraces, through either server;
  * ``tracecheck_zero_retrace`` — the same contract enforced by
    ``repro.analysis.tracecheck``: a dedicated primed-server probe runs
    under ``tracecheck(steady_state=True)``, so a violation names the
    exact retracing call site (recorded in ``gate.tracecheck_report``)
    instead of a jit-cache-size delta;
  * ``dispatch_ge_unfused_b16`` (apc) and ``dispatch_ge_unfused_b1``
    (cimmino) — the DISPATCHED serving path must not regress below the
    unfused step it can always fall back to.  This supersedes PR5's raw
    ``kernel_ge_unfused_b16`` gate: the engine autotune now includes
    "unfused" as a candidate per (family, p, n, k, dtype), so the
    invariant the serving layer owns is "dispatch picks a non-losing
    engine" (the cimmino batch-1 cell was 0.88x when always-fused — the
    BENCH_PR5 regression this PR fixes).  Raw kernel speedups stay on
    record as trend data, ungated (interpret-mode absolutes drift with
    host load).
  * ``async_ge_sync_saturation`` — at 2x the sync saturation rate the
    pipelined server must sustain at least the sync throughput.  The
    async win comes from filling host cores the sync loop leaves idle
    between device calls; on a SINGLE-core host the sync loop already
    sits at the makespan floor (total CPU work / 1 core), so the gate
    degrades to an overhead bound (async >= 0.80x sync) there — the
    recorded ``host_cpus`` says which bar applied.
  * ``p99_recorded`` — finite tail latencies for both servers;
  * ``overload_sheds`` — the overload probe sheds (> 0) and every
    request still gets an explicit answer (served + shed == submitted).

PR8 adds the system-mode rows on top (the PR6 gates carry unchanged):

  * ``benchmarks.periter.sparse_comparison``: sparse-vs-densified
    per-iteration times on a >= 90%-sparse banded system, gated
    ``sparse_ge_densified`` — the compressed path must not lose to the
    densified twin it is numerically identical to;
  * ``benchmarks.serve_traffic.streaming``: 100 perturbed-b requests
    through ``solve_stream`` on BOTH servers with a warm_rhs_ok solver,
    gated ``stream_warm_hits`` (every post-priming batch warm) and
    ``stream_zero_retrace`` (steady-state jit cache constant).

PR9 adds the roofline-push rows (all earlier gates carry unchanged):

  * ``benchmarks.periter.sparse_kernel_comparison``: the fused
    compressed-support Pallas pair vs the unfused sparse step on the
    same >= 90%-sparse banded system, gated
    ``sparse_dispatch_ge_unfused_b16`` — the DISPATCHED sparse path
    (engine autotune may pick either engine) must not lose to the
    unfused step it can fall back to.  Raw sparse-kernel speedups stay
    on record ungated (interpret-mode absolutes are not TPU perf).
  * ``benchmarks.periter.fused_residual_comparison``: in-step residual
    harvest vs a separate ||AX-b|| pass at batch 16, gated
    ``fused_residual_ge_separate_b16`` at the same noise floor.
  * ``benchmarks.roofline.live_cells``: the live bytes-vs-FLOPs model
    per kernel cell with measured ceilings — recorded (attainment per
    cell), ungated: attainment on a loaded CPU lane is a trend number.

PR10 adds the elastic-runtime chaos rows (all earlier gates carry
unchanged):

  * ``benchmarks.chaos.death_only``: one covered worker killed mid-run,
    gated ``elastic_death_exact`` — the re-lowered schedule loses ZERO
    iterations and the residual history matches the oracle (the
    redundant exactness invariant, now reached via the membership-event
    stream instead of a fixed alive_schedule);
  * ``benchmarks.chaos.chaos``: the kill -> replace -> grow schedule,
    gated ``elastic_iters_lost_bounded`` (the repartition lift may cost
    iterations, bounded by ``ELASTIC_LOST_MAX``),
    ``elastic_converged`` (final x within 1e-6 relative of the oracle),
    and ``elastic_zero_retrace`` (once the fleet settles, engine jit
    caches are FLAT — membership changes never cost a steady-state
    retrace).

    PYTHONPATH=src python scripts/bench_ci.py --out BENCH_PR10.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for d in (REPO, os.path.join(REPO, "src")):
    if d not in sys.path:
        sys.path.insert(0, d)

import jax  # noqa: E402

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402

# Benchmark shapes (quick mode: the bench lane runs this every push).
# p = n/m = 256 rows per worker on a single BN tile is the store-served
# worker block; batch 16 is the serving batch; the traffic shapes match
# benchmarks.serve_traffic.run.
PERITER = dict(n=512, m=2, batches=(1, 16), iters=30)
SERVE = dict(n=256, m=4, iters=100, warm_batches=6)
TRAFFIC = dict(n_requests=32, iters=100)
SPARSE = dict(n=768, m=4, bandwidth=8, iters=30)
SPARSE_KERNEL = dict(n=768, m=4, bandwidth=8, iters=30, batches=(1, 16))
FUSED_RES = dict(n=512, m=4, bandwidth=8, k=16, iters=30)
STREAM = dict(n_requests=100, iters=100, solver="dhbm")
CHAOS = dict(n=256, m=8, iters=400, segment=25, tol=1e-8)
ELASTIC_LOST_MAX = 50       # <= 2 segments of momentum lost to a lift
DISPATCH_MIN = 0.75         # noise floor for dispatch >= unfused gates
SPARSE_MIN = 1.0            # compressed path never loses to densified
ASYNC_MIN_MULTICORE = 1.00  # strict: the pipeline must win with cores
ASYNC_MIN_SINGLECORE = 0.80  # overhead bound at the 1-core makespan floor


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_PR10.json",
                    help="where to write the benchmark trajectory record")
    ap.add_argument("--no-gate", action="store_true",
                    help="record only; do not fail on gate violations "
                         "(bootstrap / exotic hardware)")
    args = ap.parse_args(argv)

    # measured engine autotune for the periter matrix: the dispatch rows
    # must reflect what THIS host's executors would compile
    os.environ.setdefault("REPRO_KERNEL_AUTOTUNE", "1")

    from benchmarks import periter, serve_traffic
    from repro.analysis import TraceError, tracecheck
    from repro.data import linsys
    from repro.kernels import block_projection as bp
    from repro.kernels import ops as kops
    from repro.solvers import FactorStore, LinsysServer

    print(f"== bench_ci: periter kernel/dispatch comparison {PERITER} ==")
    per = periter.kernel_comparison(**PERITER)
    for name, row in per["methods"].items():
        for k in (1, 16):
            print(f"  {name:10s} b{k:<2d} unfused {row[f'unfused_b{k}_us']:9.1f}us  "
                  f"kernel {row[f'kernel_b{k}_us']:9.1f}us "
                  f"({row[f'kernel_speedup_b{k}']:.2f}x)  "
                  f"dispatch {row[f'dispatch_b{k}_us']:9.1f}us "
                  f"({row[f'dispatch_speedup_b{k}']:.2f}x, "
                  f"{row[f'engine_b{k}']})")

    print(f"== bench_ci: periter sparse-vs-densified {SPARSE} ==")
    sc = periter.sparse_comparison(**SPARSE)
    for name, row in sc["methods"].items():
        print(f"  {name:10s} sparse {row['sparse_us']:9.1f}us  "
              f"dense {row['dense_us']:9.1f}us "
              f"({row['sparse_speedup']:.2f}x, {sc['sparsity']:.0%} zero, "
              f"w={sc['support_width']}/{sc['n']})")
    assert sc["sparsity"] >= 0.90, (
        f"sparse gate shape must be >= 90% sparse, got {sc['sparsity']:.0%}")

    print(f"== bench_ci: periter sparse kernel dispatch {SPARSE_KERNEL} ==")
    skc = periter.sparse_kernel_comparison(**SPARSE_KERNEL)
    for name, row in skc["methods"].items():
        for k in (1, 16):
            print(f"  {name:10s} b{k:<2d} unfused "
                  f"{row[f'unfused_b{k}_us']:9.1f}us  kernel "
                  f"{row[f'kernel_b{k}_us']:9.1f}us "
                  f"({row[f'kernel_speedup_b{k}']:.2f}x)  dispatch "
                  f"{row[f'dispatch_b{k}_us']:9.1f}us "
                  f"({row[f'dispatch_speedup_b{k}']:.2f}x, "
                  f"{row[f'engine_b{k}']})")
    assert skc["sparsity"] >= 0.90, (
        f"sparse kernel gate shape must be >= 90% sparse, "
        f"got {skc['sparsity']:.0%}")

    print(f"== bench_ci: periter fused residual vs separate pass "
          f"{FUSED_RES} ==")
    frc = periter.fused_residual_comparison(**FUSED_RES)
    for name, row in frc["methods"].items():
        print(f"  {name:10s} fused {row['fused_us']:9.1f}us  separate "
              f"{row['separate_us']:9.1f}us ({row['fused_speedup']:.2f}x)")

    print("== bench_ci: roofline live cells ==")
    from benchmarks import roofline
    roof = roofline.live_cells(verbose=False)
    for r in roof:
        print(f"  {r['name']:16s} {r['shape']:20s} AI {r['intensity']:5.1f} "
              f"{r['bound']:7s} attain {r['attainment']:.3f}")

    print(f"== bench_ci: serve_traffic.streaming {STREAM} ==")
    stream = {}
    for kind in ("sync", "async"):
        stream[kind] = serve_traffic.streaming(server=kind, **STREAM)
        st = stream[kind]
        print(f"  {kind:5s} {st['served']} perturbed-b requests: warm rate "
              f"{st['warm_hit_rate']:.0%}   {st['rhs_per_s']:.1f} RHS/s   "
              f"max residual {st['max_residual']:.1e}   "
              f"jit {st['jit_cache']}")

    print(f"== bench_ci: chaos elastic membership schedule {CHAOS} ==")
    from benchmarks import chaos as chaos_bench
    cd = chaos_bench.death_only(**CHAOS)
    print(f"  death_only        iters_lost={cd['iters_lost']} "
          f"history_exact={cd['history_exact']} "
          f"{cd['us_per_iter']:.0f} us/iter")
    cc = chaos_bench.chaos(**CHAOS)
    print(f"  kill_replace_grow iters_lost={cc['iters_lost']} "
          f"to_tol={cc['chaos_to_tol']} (oracle {cc['oracle_to_tol']}) "
          f"fleet {cc['m']}->{cc['fleet_final']} "
          f"reuse {cc['reused_blocks']}/{cc['prepared_blocks']} "
          f"retrace_delta={cc['retrace_delta']} "
          f"rel_err={cc['rel_err_vs_oracle']:.1e}")

    print(f"== bench_ci: serve_traffic.measure {SERVE} ==")
    srv = serve_traffic.measure(**SERVE)
    print(f"  cold {srv['cold_s']*1e3:.1f} ms   warm {srv['warm_s']*1e3:.1f}"
          f" ms   ({srv['speedup']:.1f}x, {srv['rhs_per_s']:.1f} RHS/s, "
          f"jit cache {srv['jit_cache_tail']})")

    # attributed zero-retrace probe: the same steady-state contract the
    # zero_retrace gates count via jit_cache_size, enforced here by
    # tracecheck — on violation the failure NAMES the retracing call
    # site instead of reporting a cache-size delta
    print("== bench_ci: attributed zero-retrace probe (tracecheck) ==")
    psys = linsys.conditioned_gaussian(n=SERVE["n"], m=SERVE["m"],
                                       cond=10.0, seed=0)
    psrv = LinsysServer(FactorStore(), solver="apc", iters=20, batch=2)
    pfp = psrv.register(psys)
    prng = np.random.default_rng(0)
    for _ in range(2):      # warmup compiles the keyed executor
        psrv.submit(pfp, prng.standard_normal(SERVE["n"]))
        psrv.submit(pfp, prng.standard_normal(SERVE["n"]))
        psrv.drain()
    retrace_report = ""
    try:
        with tracecheck(steady_state=True):
            for _ in range(3):
                psrv.submit(pfp, prng.standard_normal(SERVE["n"]))
                psrv.submit(pfp, prng.standard_normal(SERVE["n"]))
                psrv.drain()
        print("  steady state clean: 0 attributed trace events")
    except TraceError as e:
        retrace_report = str(e)
        print(f"  {e}", file=sys.stderr)

    cpus = serve_traffic.host_cpus()
    # pipeline depth beyond the available cores only adds timeslicing:
    # overlap 2 batches where 2 cores exist, 1 otherwise
    depth = 2 if cpus >= 2 else 1
    print(f"== bench_ci: open-loop traffic (host_cpus={cpus}, "
          f"pipeline_depth={depth}) ==")
    cap = serve_traffic.saturation_throughput(n_requests=24,
                                              iters=TRAFFIC["iters"])
    rate = 2.0 * cap
    tr = {}
    for kind in ("sync", "async"):
        tr[kind] = serve_traffic.traffic(server=kind, rate=rate,
                                         pipeline_depth=depth, **TRAFFIC)
        t = tr[kind]
        print(f"  {kind:5s} @{rate:6.1f} req/s: "
              f"{t['throughput_rhs_s']:6.1f} RHS/s   p50/p95/p99 "
              f"{t['p50_ms']:.0f}/{t['p95_ms']:.0f}/{t['p99_ms']:.0f} ms   "
              f"shed {t['shed_rate']:.2f}   jit {t['jit_cache']}")
    overload = serve_traffic.traffic(server="async", rate=float("inf"),
                                     admit_capacity=8, **TRAFFIC)
    print(f"  overload (capacity 8, t=0 burst): served {overload['served']} "
          f"shed {overload['shed']} (rate {overload['shed_rate']:.2f})")

    ratio = tr["async"]["throughput_rhs_s"] / max(
        tr["sync"]["throughput_rhs_s"], 1e-9)
    async_min = ASYNC_MIN_MULTICORE if cpus >= 2 else ASYNC_MIN_SINGLECORE
    disp_b1 = per["methods"]["cimmino"]["dispatch_speedup_b1"]
    disp_b16 = per["methods"]["apc"]["dispatch_speedup_b16"]
    gates = {
        # the dispatched serving path never loses to its fallback
        "dispatch_ge_unfused_b1": disp_b1 >= DISPATCH_MIN,
        "dispatch_ge_unfused_b16": disp_b16 >= DISPATCH_MIN,
        # steady-state serving must never retrace, either server
        "zero_retrace": bool(srv["zero_retrace"]),
        "async_zero_retrace": bool(tr["async"]["zero_retrace"]),
        # same contract, attributed: tracecheck names the call site
        "tracecheck_zero_retrace": not retrace_report,
        # the pipeline sustains sync throughput at saturation (strict
        # win with host parallelism, overhead bound on 1 core)
        "async_ge_sync_saturation": ratio >= async_min,
        # tail latency is on record for both servers
        "p99_recorded": all(np.isfinite(tr[k]["p99_ms"])
                            for k in ("sync", "async")),
        # overload degrades availability EXPLICITLY, never unboundedly
        "overload_sheds": (overload["shed"] > 0 and
                           overload["served"] + overload["shed"]
                           == TRAFFIC["n_requests"]),
        # the compressed sparse path never loses to its densified twin
        "sparse_ge_densified": all(
            row["sparse_speedup"] >= SPARSE_MIN
            for row in sc["methods"].values()),
        # the dispatched SPARSE kernel path never loses to the unfused
        # sparse step it can fall back to (the PR9 tentpole's invariant)
        "sparse_dispatch_ge_unfused_b16": all(
            row["dispatch_speedup_b16"] >= DISPATCH_MIN
            for row in skc["methods"].values()),
        # in-step residual harvest never loses to the separate pass
        "fused_residual_ge_separate_b16": all(
            row["fused_speedup"] >= DISPATCH_MIN
            for row in frc["methods"].values()),
        # streaming mode: every post-priming perturbed-b batch resumes
        # warm (warm_rhs_ok solver), through BOTH servers...
        "stream_warm_hits": all(
            stream[k]["warm_hit_rate"] == 1.0 for k in ("sync", "async")),
        # ...with a constant steady-state jit cache
        "stream_zero_retrace": all(
            stream[k]["zero_retrace"] for k in ("sync", "async")),
        # a covered death re-lowers the schedule and loses NOTHING
        "elastic_death_exact": (cd["history_exact"]
                                and cd["iters_lost"] == 0),
        # the repartition lift may cost momentum, boundedly
        "elastic_iters_lost_bounded": (
            cc["iters_lost"] is not None
            and cc["iters_lost"] <= ELASTIC_LOST_MAX),
        # the chaos run still lands on the oracle solution
        "elastic_converged": cc["rel_err_vs_oracle"] <= 1e-6,
        # after the fleet settles, engine jit caches stay flat
        "elastic_zero_retrace": cc["retrace_delta"] == 0,
    }
    record = {
        "schema": 5,
        "pr": 10,
        "backend": jax.default_backend(),
        "pallas_interpret": bp.default_interpret(),
        "host_cpus": cpus,
        "gate": {
            "cimmino_dispatch_speedup_b1": disp_b1,
            "apc_dispatch_speedup_b16": disp_b16,
            "dispatch_min": DISPATCH_MIN,
            "sync_saturation_rhs_s": cap,
            "traffic_rate_rps": rate,
            "async_vs_sync_throughput": ratio,
            "async_min": async_min,
            "pipeline_depth": depth,
            "tracecheck_report": retrace_report,
            "sparse_speedups": {name: row["sparse_speedup"]
                                for name, row in sc["methods"].items()},
            "sparse_dispatch_speedups_b16": {
                name: row["dispatch_speedup_b16"]
                for name, row in skc["methods"].items()},
            "fused_residual_speedups": {
                name: row["fused_speedup"]
                for name, row in frc["methods"].items()},
            "roofline_attainment": {r["name"]: r["attainment"]
                                    for r in roof},
            "sparse_min": SPARSE_MIN,
            "sparse_gate_sparsity": sc["sparsity"],
            "stream_warm_rates": {k: stream[k]["warm_hit_rate"]
                                  for k in ("sync", "async")},
            "elastic_iters_lost": cc["iters_lost"],
            "elastic_lost_max": ELASTIC_LOST_MAX,
            "elastic_rel_err_vs_oracle": cc["rel_err_vs_oracle"],
            "elastic_retrace_delta": cc["retrace_delta"],
        },
        "engine_choices": {str(k): v
                           for k, v in sorted(kops.engine_cache().items())},
        "periter_kernel": per,
        "periter_sparse": sc,
        "periter_sparse_kernel": skc,
        "periter_fused_residual": frc,
        "roofline": roof,
        "serve_traffic": srv,
        "streaming": stream,
        "chaos": {"death_only": cd, "kill_replace_grow": cc},
        "traffic": {"sync": tr["sync"], "async": tr["async"],
                    "overload": overload},
        "gates": gates,
    }
    with open(args.out, "w") as f:
        json.dump(record, f, indent=2)
    print(f"wrote {args.out}")

    sparse_min_seen = min(row["sparse_speedup"]
                          for row in sc["methods"].values())
    sk_min_seen = min(row["dispatch_speedup_b16"]
                      for row in skc["methods"].values())
    fr_min_seen = min(row["fused_speedup"]
                      for row in frc["methods"].values())
    failed = [k for k, ok in gates.items() if not ok]
    if failed:
        msg = (f"bench gate FAILED: {failed} "
               f"(dispatch b1={disp_b1:.2f}x b16={disp_b16:.2f}x, "
               f"sparse>={sparse_min_seen:.2f}x, "
               f"sparse-dispatch b16>={sk_min_seen:.2f}x, "
               f"fused-residual>={fr_min_seen:.2f}x, "
               f"stream warm {stream['sync']['warm_hit_rate']:.0%}/"
               f"{stream['async']['warm_hit_rate']:.0%}, "
               f"elastic lost={cc['iters_lost']} vs <={ELASTIC_LOST_MAX} "
               f"retrace_delta={cc['retrace_delta']}, "
               f"async/sync={ratio:.2f} vs >={async_min:.2f} "
               f"on {cpus} cpu(s))")
        if args.no_gate:
            print(f"WARNING (--no-gate): {msg}")
            return 0
        print(msg, file=sys.stderr)
        return 1
    print(f"bench gates OK: dispatch b1 {disp_b1:.2f}x / b16 {disp_b16:.2f}x "
          f">= {DISPATCH_MIN}, sparse {sparse_min_seen:.2f}x >= "
          f"{SPARSE_MIN} at {sc['sparsity']:.0%} sparsity, sparse-dispatch "
          f"b16 {sk_min_seen:.2f}x / fused-residual {fr_min_seen:.2f}x >= "
          f"{DISPATCH_MIN}, stream warm "
          f"100% both servers, async/sync {ratio:.2f} >= {async_min:.2f} "
          f"({cpus} cpu(s)), zero retraces, overload sheds explicitly, "
          f"elastic death exact / lost {cc['iters_lost']} <= "
          f"{ELASTIC_LOST_MAX} / settled caches flat")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
