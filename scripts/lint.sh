#!/usr/bin/env bash
# Lint entry point for tier-1 CI (and local use):
#
#     bash scripts/lint.sh [paths...]
#
# 1. ruff (generic baseline: unused/undefined bindings, comparison and
#    except foot-guns — config in pyproject.toml).  Skipped with a note
#    when ruff is not installed locally; the hosted lanes install it via
#    scripts/requirements-ci.txt, so CI always runs it.
# 2. reprolint (python -m repro.analysis): the repo-specific contract
#    rules R001-R007 + the lock-discipline checker L001-L003.  See
#    ROADMAP.md "Static analysis & contract checks".
#
# Exit status is non-zero if either stage finds anything.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if command -v ruff >/dev/null 2>&1; then
  echo "== ruff (generic lint baseline) =="
  ruff check "${@:-.}"
else
  echo "== ruff not installed; skipping generic baseline (hosted CI runs it) =="
fi

echo "== reprolint (repro.analysis contract checks) =="
python -m repro.analysis "$@"

echo "LINT OK"
