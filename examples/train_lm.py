"""End-to-end training driver example: a reduced TinyLlama-family model for
a few hundred steps on CPU with checkpointing, via the production launcher.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]

(The same launcher drives the full configs on a pod — see
src/repro/launch/train.py and the dry-run for the production meshes.)
"""
import argparse
import sys

from repro.launch import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="tinyllama-1.1b")
    args = ap.parse_args()
    return train.main([
        "--arch", args.arch, "--smoke",
        "--steps", str(args.steps),
        "--batch", "8", "--seq", "128",
        "--ckpt-dir", "/tmp/repro_train_lm_ckpt",
        "--ckpt-every", "50", "--log-every", "10",
    ])


if __name__ == "__main__":
    sys.exit(main())
