"""Distributed APC on a device mesh (shard_map production path).

Forces 8 placeholder CPU devices so the (4 workers x 2 column-shards) mesh
exists on any machine:

    PYTHONPATH=src python examples/distributed_solve.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402

from repro import solvers  # noqa: E402
from repro.core import distributed  # noqa: E402
from repro.data import linsys  # noqa: E402
from repro.launch import mesh as mesh_lib  # noqa: E402


def main():
    mesh = mesh_lib.solver_mesh(workers=4, model=2)
    print("mesh:", mesh)

    sys_ = linsys.conditioned_gaussian(n=256, m=4, cond=30.0, seed=1)
    xbar, residual = distributed.solve_on_mesh(mesh, sys_, iters=400)
    err = float(np.linalg.norm(np.asarray(xbar) - np.asarray(sys_.x_true)) /
                np.linalg.norm(np.asarray(sys_.x_true)))
    print(f"distributed APC: residual {residual:.3e}  rel-error {err:.3e}")

    # single-host reference through the unified registry surface
    ref = solvers.get("apc").solve(sys_, iters=400)
    d = float(np.linalg.norm(np.asarray(xbar) - np.asarray(ref.x)))
    print(f"max deviation from single-host reference: {d:.3e}")
    assert d < 1e-8


if __name__ == "__main__":
    main()
