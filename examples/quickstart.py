"""Quickstart: solve a distributed linear system with APC and compare every
method from the paper — all through the unified solver registry:

    from repro import solvers
    result = solvers.get("apc").solve(sys_, iters=3000)
    print(solvers.available())   # all eight methods, one call path

    PYTHONPATH=src python examples/quickstart.py

Before sending a change, `bash scripts/lint.sh` runs the repo's contract
lints (jit placement, store routing, retrace discipline — see ROADMAP.md
"Static analysis & contract checks"); tier-1 CI runs the same script.
"""
import time

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402

from repro import solvers  # noqa: E402
from repro.core import spectral  # noqa: E402
from repro.data import linsys  # noqa: E402


def main():
    # A 500x500 system with controlled conditioning, split across m=4
    # workers.  (The paper's exact Table-2 ensembles — standard/nonzero-mean
    # Gaussian and the Matrix Market problems — run in benchmarks/table2;
    # they need 10^4-10^5 iterations by design, so the quickstart uses a
    # kappa where every method's behaviour is visible in 3000 iterations.)
    sys_ = linsys.conditioned_gaussian(n=500, m=4, cond=300.0, seed=0)
    print(f"system: N={sys_.N} n={sys_.n} workers={sys_.m} "
          f"(p={sys_.p} rows each)")

    # Taskmaster-side analysis: optimal rates per method (Theorem 1 / Sec 4).
    s = spectral.rates_summary(sys_)
    print(f"kappa(X) = {s['kappa_X']:.3e}   kappa(A^T A) = {s['kappa_AtA']:.3e}")
    print("optimal rates:", {k: round(v, 6) for k, v in s.items()
                             if k not in ("mu_min", "mu_max", "kappa_X",
                                          "kappa_AtA")})

    # Every method from the paper through the identical registry call path.
    iters = 3000
    for name in ["apc", "dhbm", "dnag", "cimmino", "dgd", "pdhbm"]:
        solver = solvers.get(name)
        res = solver.solve(sys_, iters=iters)
        reached = (f"residual<{res.tol:.0e} @ iter {res.iters_to_tol}"
                   if res.iters_to_tol != -1 else "tolerance not reached")
        print(f"{solver.paper_name:10s} after {iters} iters: rel-error "
              f"{float(res.errors[-1]):.3e}   ({reached})")

    # The serving hot path: one factorization, a batch of right-hand sides.
    B = np.random.default_rng(1).standard_normal((4, sys_.N))
    batch = solvers.get("apc").solve_many(sys_, B, iters=1000)
    print(f"solve_many: 4 RHS, final residuals "
          f"{[f'{float(r[-1]):.1e}' for r in batch.residuals]}")

    # Execution options travel on ONE object: solvers.ExecutionPlan
    # (backend/mesh, kernel, precision, redundancy/alive_schedule, store,
    # warm_state...).  The old loose kwargs still work for one release
    # behind a DeprecationWarning and build the identical plan.
    # The fused Pallas engine: kernel=True routes the projection
    # family (apc/consensus/cimmino) through the block-projection kernels
    # on the SAME call — single or batched RHS, local or mesh backend
    # (each worker shard runs the kernel on its local block; histories
    # match the unfused path to <= 1e-6).  Interpret mode off-TPU.
    rk = solvers.get("apc").solve_many(
        sys_, B, iters=1000, plan=solvers.ExecutionPlan(kernel=True))
    print(f"solve_many(plan=ExecutionPlan(kernel=True)): max |Δresidual| "
          f"vs unfused "
          f"{float(np.max(np.abs(np.asarray(rk.residuals) - np.asarray(batch.residuals)))):.1e}")
    from repro.launch.mesh import solver_mesh
    rkm = solvers.get("apc").solve(
        sys_, iters=1000,
        plan=solvers.ExecutionPlan(kernel=True, backend="mesh",
                                   mesh=solver_mesh(1, 1)))
    print(f"mesh + use_kernel: rel-error {float(rkm.errors[-1]):.3e} "
          f"(kernel runs inside shard_map, psum contract unchanged)")

    # Cached factorizations: repeated solves of the SAME system are the
    # other serving pattern.  A FactorStore content-addresses the one-time
    # b-independent prepare (give it a directory and factors survive
    # restarts), and LinsysServer serves a request stream from it with a
    # compile-once executor — the first batch is COLD (prepare + compile,
    # a store miss), every later one WARM (store hit, zero retraces).
    # A well-conditioned serve-scale system keeps each batch fast:
    # plan=ExecutionPlan(kernel=True) serves every coalesced batch through
    # the fused multi-RHS kernels: the k right-hand sides stream through
    # ONE VMEM residency of each A/B tile, and the store entry is
    # augmented with the pinv factors exactly once.
    serve_sys = linsys.conditioned_gaussian(n=256, m=4, cond=20.0, seed=2)
    store = solvers.FactorStore()
    srv = solvers.LinsysServer(store, solver="apc", iters=300, batch=4,
                               plan=solvers.ExecutionPlan(kernel=True))
    fp = srv.register(serve_sys)             # content fingerprint
    rng = np.random.default_rng(2)
    for tag in ("cold", "warm", "warm"):
        for _ in range(4):
            srv.submit(fp, rng.standard_normal(serve_sys.N))
        t0 = time.perf_counter()
        batch = srv.step()
        dt = time.perf_counter() - t0
        print(f"factor store, {tag} batch: 4 RHS in {dt * 1e3:7.1f} ms  "
              f"(worst residual {max(r.residual for r in batch):.1e})")
    print(f"store {store.stats}  (entry kernel-augmented once)")

    # System modes: the same registry call path covers sparse,
    # overdetermined least-squares, and streaming systems (ROADMAP
    # "System representations & modes").  Each solver declares a
    # `supports` capability set, checked at dispatch — asking pdhbm for a
    # sparse solve raises solvers.CapabilityError instead of returning
    # garbage.
    sp = linsys.banded_system(n=256, m=4, bandwidth=8, seed=3)
    rs = solvers.get("apc").solve(sp, iters=400)
    rd = solvers.get("apc").solve(sp.densified(), iters=400)
    print(f"sparse: banded n={sp.n} ({sp.sparsity:.0%} zero)  rel-error "
          f"{float(rs.errors[-1]):.3e}  |dx| vs densified "
          f"{float(np.max(np.abs(np.asarray(rs.x) - np.asarray(rd.x)))):.1e}")

    # Sparse systems are kernel-first too: kernel=True dispatches the
    # fused compressed-support Pallas pair (gather the w support columns,
    # contract the (p, w) vals / (w, p) compressed-pinv tiles, scatter-add
    # back) — silently, and with the residual history harvested inside
    # the step pass instead of a second full read of A per iteration.
    # precision="mixed" additionally streams the A/B tiles as bf16 under
    # f32 accumulation — histories track f32 within the bf16 envelope.
    rsk = solvers.get("apc").solve(
        sp, iters=400, plan=solvers.ExecutionPlan(kernel=True))
    print(f"sparse + kernel: max |Δresidual| vs unfused "
          f"{float(np.max(np.abs(np.asarray(rsk.residuals) - np.asarray(rs.residuals)))):.1e}")
    rsm = solvers.get("apc").solve(
        sp, iters=400,
        plan=solvers.ExecutionPlan(kernel=True, precision="mixed"))
    print(f"sparse + use_kernel + precision='mixed': final residual "
          f"{float(rsm.residuals[-1]):.1e} (bf16 tile streams)")

    ls = linsys.tall_gaussian(N=320, n=160, m=4, seed=3, noise=0.05)
    rl = solvers.get("dgd").solve(ls, iters=800)
    A_ls, b_ls = ls.dense()
    ref = np.linalg.lstsq(np.asarray(A_ls), np.asarray(b_ls), rcond=None)[0]
    rel = float(np.linalg.norm(np.asarray(rl.x) - ref) / np.linalg.norm(ref))
    print(f"least-squares: N={ls.N} > n={ls.n} (inconsistent)  "
          f"rel-error vs lstsq {rel:.1e}")

    # Streaming: solve_stream drives a server through a stream of
    # perturbed right-hand sides.  Warm-start solvers (gradient family +
    # cimmino) seed each solve from the previous answer, so every
    # steady-state request is a warm hit on the compiled executor.
    st_sys = linsys.conditioned_gaussian(n=192, m=4, cond=20.0, seed=4)
    ssrv = solvers.LinsysServer(store, solver="dhbm", iters=300, batch=1,
                                warm_start=True)
    sfp = ssrv.register(st_sys)
    b0 = np.asarray(st_sys.dense()[1])
    stream = [(sfp, b0 + 1e-3 * rng.standard_normal(st_sys.N))
              for _ in range(8)]
    srep = solvers.solve_stream(ssrv, stream)
    print(f"stream: {len(srep.served)} perturbed-b requests  "
          f"warm hit rate {srep.warm_hit_rate:.0%}")

    # Async pipelined serving: AsyncLinsysServer decomposes the same
    # serving contract into overlapped stages — bounded admission (a full
    # pipeline SHEDS with an explicit result instead of queueing
    # unboundedly), batch assembly + host->device transfer on a host
    # thread, up to pipeline_depth batches in flight on the compile-once
    # executors, and per-request futures streaming results back.  Same
    # store, same coalescing, same zero-retrace invariant; submit()
    # returns a Ticket immediately.
    asrv = solvers.AsyncLinsysServer(store, solver="apc", iters=300,
                                     batch=4, pipeline_depth=2,
                                     admit_capacity=64,
                                     plan=solvers.ExecutionPlan(kernel=True))
    afp = asrv.register(serve_sys)
    with asrv:                               # start()/close() the stages
        tickets = [asrv.submit(afp, rng.standard_normal(serve_sys.N))
                   for _ in range(8)]
        results = [t.result() for t in tickets]
    rep = asrv.latency_report()
    shed = sum(isinstance(r, solvers.Shed) for r in results)
    print(f"async pipeline: {asrv.stats.served} served / {shed} shed, "
          f"p50/p99 {rep['p50_ms']:.0f}/{rep['p99_ms']:.0f} ms, "
          f"worst residual "
          f"{max(r.residual for r in results if not isinstance(r, solvers.Shed)):.1e}")

    # Elastic fleets: ElasticRuntime drives the same solve across
    # membership changes from a HeartbeatMonitor.  With redundancy r, a
    # permanent worker death just re-lowers the selection weights over
    # the survivors — the iterate continues EXACTLY, zero iterations
    # lost; joins repartition + lift the iterate, reusing unchanged
    # per-block factors through the store's block tier.
    from repro.runtime.fault import HeartbeatMonitor
    el_sys = linsys.conditioned_gaussian(n=128, m=4, cond=10.0, seed=5)
    mon = HeartbeatMonitor(n_workers=el_sys.m)
    rt = solvers.ElasticRuntime(solvers.get("apc"), el_sys,
                                plan=solvers.ExecutionPlan(redundancy=2),
                                monitor=mon, segment=25)
    rt.run(iters=50)
    mon.mark_dead(2)                         # permanent loss mid-solve
    rep_el = rt.run(iters=100)
    oracle = solvers.get("apc").solve(el_sys, iters=150)
    survivors = sorted(set(rep_el.fleet) - mon.dead)
    print(f"elastic: worker 2 died @50, re-lowered over survivors "
          f"{survivors}; final residual "
          f"{float(rep_el.result.residuals[-1]):.1e} "
          f"(== full-fleet oracle {float(oracle.residuals[-1]):.1e}, "
          f"0 iterations lost)")


if __name__ == "__main__":
    main()
