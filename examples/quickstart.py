"""Quickstart: solve a distributed linear system with APC and compare every
method from the paper.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402

from repro.core import apc, baselines, precond, spectral  # noqa: E402
from repro.data import linsys  # noqa: E402


def main():
    # A 500x500 system with controlled conditioning, split across m=4
    # workers.  (The paper's exact Table-2 ensembles — standard/nonzero-mean
    # Gaussian and the Matrix Market problems — run in benchmarks/table2;
    # they need 10^4-10^5 iterations by design, so the quickstart uses a
    # kappa where every method's behaviour is visible in 3000 iterations.)
    sys_ = linsys.conditioned_gaussian(n=500, m=4, cond=300.0, seed=0)
    print(f"system: N={sys_.N} n={sys_.n} workers={sys_.m} "
          f"(p={sys_.p} rows each)")

    # Taskmaster-side analysis: optimal (gamma, eta) from Theorem 1.
    s = spectral.rates_summary(sys_)
    print(f"kappa(X) = {s['kappa_X']:.3e}   kappa(A^T A) = {s['kappa_AtA']:.3e}")
    print("optimal rates:", {k: round(v, 6) for k, v in s.items()
                             if k not in ("mu_min", "mu_max", "kappa_X",
                                          "kappa_AtA")})

    iters = 3000
    res = apc.solve(sys_, iters=iters)
    print(f"\nAPC after {iters} iters: rel-error {float(res.errors[-1]):.3e}")

    for name, fn in [("D-HBM", baselines.dhbm), ("D-NAG", baselines.dnag),
                     ("B-Cimmino", baselines.cimmino),
                     ("DGD", baselines.dgd)]:
        h = fn(sys_, iters=iters)
        print(f"{name:10s} after {iters} iters: rel-error "
              f"{float(h.errors[-1]):.3e}")

    # Section 6: distributed preconditioning gives D-HBM the APC rate.
    h = precond.preconditioned_dhbm(sys_, iters=iters)
    print(f"{'P-DHBM':10s} after {iters} iters: rel-error "
          f"{float(h.errors[-1]):.3e}   (Sec. 6 preconditioning)")


if __name__ == "__main__":
    main()
