"""Fault-tolerance demo: r-redundant APC keeps converging while workers
randomly stall, and the run is bit-identical to the no-failure run.

    PYTHONPATH=src python examples/straggler_sim.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402

from repro.core import coding  # noqa: E402
from repro.data import linsys  # noqa: E402
from repro.runtime import fault  # noqa: E402


def main():
    m, r = 8, 2
    sys_ = linsys.conditioned_gaussian(n=128, m=m, cond=20.0, seed=3)
    rng = np.random.default_rng(0)

    def alive_schedule(t):
        """One random straggler every iteration (but never an uncovered
        pattern — the monitor would trigger a re-partition otherwise)."""
        a = np.ones(m, bool)
        a[rng.integers(0, m)] = False
        assert fault.covering_ok(a, r)
        return a

    x_clean, res_clean = coding.solve_redundant(sys_, r, iters=300)
    rng = np.random.default_rng(0)
    x_fail, res_fail = coding.solve_redundant(sys_, r, iters=300,
                                              alive_schedule=alive_schedule)
    print(f"no-failure final residual:   {res_clean[-1]:.3e}")
    print(f"with-straggler residual:     {res_fail[-1]:.3e}")
    print(f"iterate deviation:           "
          f"{float(np.abs(np.asarray(x_clean) - np.asarray(x_fail)).max()):.3e}")
    print("straggler mitigation is EXACT (coding.py invariant)")


if __name__ == "__main__":
    main()
