"""Fault-tolerance demo on the unified solver API: redundant execution
(``solve(sys, plan=ExecutionPlan(redundancy=r, alive_schedule=...))``,
solvers/redundant.py) keeps converging while workers randomly stall, and
the run matches the no-failure run exactly — on any projection-family
solver.  Also shows a
``runtime.fault.HeartbeatMonitor`` as the alive-mask source: its
``drop_set()`` (dead OR straggling workers) is snapshotted when the
schedule is lowered at launch (re-lower via warm-started segments to
track mid-run health changes).

    PYTHONPATH=src python examples/straggler_sim.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402

from repro import solvers  # noqa: E402
from repro.data import linsys  # noqa: E402
from repro.runtime import fault  # noqa: E402


def main():
    m, r = 8, 2
    sys_ = linsys.conditioned_gaussian(n=128, m=m, cond=20.0, seed=3)
    rng = np.random.default_rng(0)

    def alive_schedule(t):
        """One random straggler every iteration (but never an uncovered
        pattern — the monitor would trigger a re-partition otherwise)."""
        a = np.ones(m, bool)
        a[rng.integers(0, m)] = False
        assert fault.covering_ok(a, r)
        return a

    apc = solvers.get("apc")
    clean = apc.solve(sys_, iters=300)
    failing = apc.solve(sys_, iters=300,
                        plan=solvers.ExecutionPlan(
                            redundancy=r, alive_schedule=alive_schedule))
    dev = float(np.abs(np.asarray(clean.x) - np.asarray(failing.x)).max())
    print(f"no-failure final residual:   {clean.residuals[-1]:.3e}")
    print(f"with-straggler residual:     {failing.residuals[-1]:.3e}")
    print(f"iterate deviation:           {dev:.3e}")
    print("straggler mitigation is EXACT (solvers/redundant.py invariant)")

    # live alive-masks from the heartbeat runtime: worker 5 goes silent,
    # worker 2 is 5x slower than the median -> both land in drop_set()
    import time
    mon = fault.HeartbeatMonitor(n_workers=m, timeout=60.0,
                                 straggler_factor=3.0)
    now = time.monotonic()
    for w in range(m):
        mon.beat(w, now=now, duration=5.0 if w == 2 else 1.0)
    mon.mark_dead(5)
    dropped = [int(w) for w in np.flatnonzero(mon.drop_set())]
    monitored = apc.solve(sys_, iters=300,
                          plan=solvers.ExecutionPlan(redundancy=r,
                                                     alive_schedule=mon))
    dev_m = float(np.abs(np.asarray(clean.x) - np.asarray(monitored.x)).max())
    print(f"monitor drops workers {dropped}; residual "
          f"{monitored.residuals[-1]:.3e}  deviation {dev_m:.3e}")


if __name__ == "__main__":
    main()
