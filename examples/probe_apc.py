"""APC inside the LM framework: fit a linear probe on hidden states with the
paper's distributed solver (optim/apc_head.py), instead of SGD.

A reduced qwen3-family model produces hidden states H; the probe target is
a synthetic linear functional of H plus noise.  APC solves the ridge normal
equations distributed over m=8 row-blocks and matches the closed form.

    PYTHONPATH=src python examples/probe_apc.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro import configs  # noqa: E402
from repro.models import model, sharding  # noqa: E402
from repro.optim import apc_head  # noqa: E402


def main():
    cfg = configs.get_smoke("qwen3-4b")
    params = sharding.init_tree(model.model_abstract(cfg),
                                jax.random.PRNGKey(0), jnp.float32)
    B, S = 8, 64
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    rules = sharding.Rules(batch=("data",), fsdp=None, tensor=None,
                           seq_sp=None, kv_seq=None)
    logits = model.forward(cfg, params, {"tokens": toks}, rules=rules)
    # hidden states = pre-head activations; re-derive via the embedding trick
    # (for the example we just use the logits' top-64 slice as features).
    H = np.asarray(logits[..., :64].reshape(B * S, 64), np.float64)
    H = (H - H.mean(0)) / (H.std(0) + 1e-9)     # standardized features
    rng = np.random.default_rng(2)
    w_true = rng.standard_normal(64)
    y = H @ w_true + 0.01 * rng.standard_normal(H.shape[0])

    # Hidden activations of an untrained LM are heavily correlated across
    # positions, so the probe needs real ridge regularization — lam also
    # sets kappa(X) and hence APC's iteration count.
    lam = 10.0
    w, residuals = apc_head.fit_probe(jnp.asarray(H), jnp.asarray(y),
                                      m=4, lam=lam, iters=2000)
    A, b = apc_head.normal_system(jnp.asarray(H), jnp.asarray(y), lam)
    w_ref = np.linalg.solve(np.asarray(A), np.asarray(b))
    err = float(np.linalg.norm(np.asarray(w) - w_ref) /
                np.linalg.norm(w_ref))
    print(f"probe fit over {H.shape[0]} tokens, 64 features, m=4 workers")
    print(f"APC residual history: {residuals[0]:.2e} -> {residuals[-1]:.2e}")
    print(f"deviation from closed-form ridge solution: {err:.3e}")
    print(f"probe MSE: {apc_head.probe_loss(jnp.asarray(H), jnp.asarray(y), w):.4e}")
    assert err < 1e-3


if __name__ == "__main__":
    main()
